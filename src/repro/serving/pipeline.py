"""MultiWorld pipeline server — the paper's Fig. 2 with real models.

Topology: the model is split into N stages (serving/partition.py); each stage
has one or more replica workers; every (upstream replica, downstream replica)
pair gets its own pairwise world, as does every (client, stage-0 replica) and
(last-stage replica, client) pair. Worlds are fault domains: a replica death
breaks only its edges; upstream routers drop the broken worlds and keep
serving through the survivors; ``add_replica`` performs online instantiation
(new worker + fresh worlds) without touching any existing world.

Generative data plane (beyond the paper's one-shot batches): every payload on
every edge is a typed :class:`~repro.serving.envelope.Envelope`. The client
drives autoregressive generation with ``generate()``:

* PREFILL carries the full token history through the pipeline; each stage
  builds a per-session KV cache over its own layer slice and *pins* the
  downstream world it picked, so the session's decode steps follow one route.
* DECODE carries one token per step along the pinned route. Each replica runs
  a continuous-batching micro-scheduler: compatible queued decode steps (same
  per-session batch shape, arbitrary positions) coalesce into one fused
  ``decode_many`` dispatch, with a max-wait knob (``microbatch_wait_s``)
  bounding the latency paid for batching.
* A replica that has lost a session's state — it is draining, the session
  was never prefilled here, or its pinned downstream edge died — answers
  RETRY toward the client, which re-prefills the full history (prompt + all
  tokens generated so far) on a survivor: at-least-once, state rebuilt,
  zero client-visible token loss.
* FINISH releases per-stage session state along the pinned route.

State transfer (repro.statexfer) upgrades the recovery paths so RETRY +
full re-prefill is the *fallback*, not the norm:

* planned drain hands every open session off live — the MigrationManager
  freezes it at a step boundary (new steps pile into ``held``), streams its
  KV snapshot to a same-stage survivor, flips the pins, and releases the
  held steps into the survivor's inbox: zero re-prefill, token-identical;
* an unplanned kill restores from the SnapshotStore's background snapshots
  and the client replays only the tokens since the latest snapshot;
* a deadline-expired envelope is dropped at the stage boundary with a
  FINISH(error) propagated to the client instead of being served late.

Disaggregated prefill/decode pools (role-specialized replicas): a stage's
replica count may be given as ``{"prefill": p, "decode": d}`` instead of an
int, splitting the stage into a prefill pool (serves PREFILL/SCORE — long,
compute-bound, compile-heavy dispatches) and a decode pool (serves DECODE —
short, latency-bound, batch-hungry steps), each scalable on its own signal.
The two pools meet at the *handoff*: a prefill replica builds the session's
stage-slice KV cache, streams it to a placement-ranked decode-pool home over
the statexfer chunked codec (HANDOFF envelopes), and stitches the decode
route's pins onto that home — so every subsequent decode step bypasses the
prefill pool entirely, and a burst of long prompts can no longer convoy
decode microbatches behind prefill dispatches. ``role='both'`` (the default
for int counts) keeps the colocated behavior bit-identical: caches install
locally and no handoff ever runs. A failed handoff unwinds to RETRY + full
re-prefill on the prefill pool — never a new failure mode.

Multi-model, multi-tenant pool (the consolidation refactor): the pipeline
can host several registered models on one elastic replica set instead of
one-model-one-server. A :class:`~repro.serving.registry.ModelRegistry`
tracks which models exist and where they are resident (refcounted by open
sessions, LRU-evictable); ``load_model``/``unload_model``/``swap_model``
drive the LOAD/UNLOAD/SWAP envelope protocol (statexfer.bootstrap) that
streams a model's stage weights from a resident peer — or cold from the
registry store — *without the replica ever leaving rotation*. Every
envelope carries its model tag; routers restrict rotation to replicas with
the model resident; executors are keyed per (model, stage, role) so compile
caches and KV pools never mix models. Tenancy rides the same envelopes: the
decode micro-scheduler arbitrates batch slots across tenants by weighted
deficit round-robin (``tenant_weights``), and the client keys TTFT/decode
latency sketches per tenant so per-tenant SLO policies have real signals.
Defaults (no registry, no tags, one implicit tenant) preserve single-model
behavior bit-for-bit.

Elastic control hooks (consumed by repro.control):

* ``remove_replica`` — scale-down: stop routing to the replica, *unpin* its
  sessions (their next decode step triggers relocation via RETRY or the
  client's own pin check), drain its inbox/in-flight work/adjacent channels
  to zero, then tear down its worlds in one event-loop tick.
* per-replica load counters (queue depth, in-flight, wait/service time,
  tokens out, open sessions) — the raw signals MetricsHub turns into EWMAs.
* ``failed_replicas`` — watchdog-sourced failure view for the heal loop.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Cluster,
    WorldBrokenError,
    WorldNotFoundError,
    WorldSpec,
)
from repro.core.online import OnlineInstantiator
from repro.obs import FlightRecorder, LogSketch, Tracer
from repro.statexfer import (
    INT8,
    MigrationManager,
    SnapshotStore,
    WarmBootstrap,
    argmax_margin,
    cache_nbytes,
)
from .envelope import (
    Envelope,
    Kind,
    ROLE_BOTH,
    ROLE_CAPABLE,
    ROLE_DECODE,
    ROLE_DRAFT,
    ROLE_PREFILL,
)
from .executor import StageExecutor
from .partition import split_stages, stage_params
from .registry import ModelRegistry, ResidencyError
from .router import ReplicaRouter

CLIENT = "client"


def _edge(name: str, up: str, down: str) -> str:
    return f"{name}:{up}->{down}"


@dataclasses.dataclass
class _Session:
    """Per-stage decode state for one open generation request."""

    cache: Any
    batch: int
    step: int            # last position decoded at this stage
    touched: float       # monotonic; TTL reaping of orphaned state
    #: TraceContext of the step that installed this state — migration,
    #: snapshot, and heal spans for the session parent here, keeping the
    #: control-plane work inside the session's causal tree
    trace: Any = None
    #: registered model this session runs (None = the pipeline default);
    #: decode steps resolve their executor — and batch-mates — through it
    model: Optional[str] = None
    #: tenant whose traffic this session is (fair-scheduler accounting)
    tenant: Optional[str] = None


class _SessionLost(Exception):
    """Client-side marker: pinned state gone; re-prefill on a survivor."""


class _Replica:
    def __init__(self, server: "PipelineServer", worker_id: str,
                 stage: int, role: str = ROLE_BOTH) -> None:
        self.server = server
        self.worker_id = worker_id
        self.stage = stage
        #: which pool this replica serves: ``both`` (colocated default),
        #: ``prefill`` (builds caches, hands them off, never decodes), or
        #: ``decode`` (receives caches over the handoff, serves every step)
        self.role = role
        #: models resident on this replica — the routing tag its upstream
        #: edges carry and the unit load_model/unload_model/swap_model
        #: mutate. Always contains at least the pipeline's default model;
        #: further models join via the LOAD protocol after wiring.
        self.resident: set[str] = {server.default_model}
        self.worker = server.cluster.worker(worker_id)
        #: compute executor for the *default* model — shared per
        #: (stage, role) unless WarmBootstrap installed a fresh per-replica
        #: executor (new-process simulation). Non-default models resolve
        #: through :meth:`executor_for` to per-(model, stage, role)
        #: executors shared at the server.
        self.executor = server.role_executor(stage, role)
        self.upstream: list[str] = []          # world names we recv on
        #: (world, upstream router that routes onto it) — scale-down needs to
        #: know exactly which rotation each inbound edge lives in
        self.upstream_edges: list[tuple[str, ReplicaRouter]] = []
        self.router = ReplicaRouter()          # downstream worlds we send on
        self.router.set_load_probe(server._edge_load)
        self.router.set_drop_listener(server._forget_edge)
        self.inbox: asyncio.Queue = asyncio.Queue()
        #: envelopes popped during decode coalescing that must be served
        #: before the next inbox read (ordering across kinds)
        self._stash: deque = deque()
        #: open generation sessions whose stage-slice KV cache lives here
        self.sessions: dict[int, _Session] = {}
        #: sessions frozen mid-migration: sid -> held (env, t_enq) items,
        #: released into the survivor's inbox once the handoff installs
        self.held: dict[int, list] = {}
        #: sessions handed off from here: sid -> survivor replica; late
        #: arrivals (already in our channels when the pins flipped) are
        #: forwarded instead of bounced into a useless re-prefill
        self.migrated: dict[int, "_Replica"] = {}
        #: sessions with a decode step currently executing/coalescing — the
        #: MigrationManager waits for a step boundary before snapshotting
        self.active: set[int] = set()
        #: persistent prefill<->decode handoff worlds this replica is an
        #: endpoint of (steady-state KV transfer channels; torn down with
        #: the replica)
        self.handoff_worlds: set[str] = set()
        self._pumps: dict[str, asyncio.Task] = {}
        self._run_task: Optional[asyncio.Task] = None
        self._reap_task: Optional[asyncio.Task] = None
        self.draining = False
        self._last_reap = time.monotonic()
        # -- load/latency counters polled by control.MetricsHub ------------
        self.processed = 0
        self.inflight = 0
        self.wait_s_sum = 0.0        # inbox sojourn
        self.service_s_sum = 0.0     # compute + downstream send
        self.parked = 0              # sends parked on an empty rotation
        self.tokens_out = 0          # decode tokens produced (B per step)
        self.decode_batches = 0      # fused decode dispatches
        self.decode_steps = 0        # decode envelopes served
        self.retries_sent = 0        # sessions bounced back for re-prefill
        self.expired = 0             # envelopes dropped past their deadline
        # -- per-kind latency split (MetricsHub turns the deltas into TTFT
        #    vs per-token decode EWMAs — the per-role policies' signals) ---
        self.prefills = 0            # prefills served (incl. handoff time)
        self.prefill_s_sum = 0.0     # wall time of served prefills
        self.decode_s_sum = 0.0      # wall time of fused decode dispatches
        self.handoffs_out = 0        # prefills handed to the decode pool
        # -- mergeable latency distributions: one O(1) sketch insert per
        #    dispatch; MetricsHub folds these into the stage/fleet digests
        #    so p95 TTFT / p99 decode survive aggregation (means cannot) --
        self.ttft_sketch = LogSketch()
        self.decode_sketch = LogSketch()
        # -- speculative decoding counters (control-plane acceptance
        #    signal: MetricsHub folds proposed/accepted deltas into the
        #    per-replica acceptance EWMA that SpecDecodePolicy votes on) --
        self.spec_verifies = 0       # fused VERIFY dispatches served here
        self.spec_proposed = 0       # draft tokens offered to verification
        self.spec_accepted = 0       # draft tokens verification accepted
        self.spec_proposals = 0      # PROPOSE rounds served (draft pool)
        # -- weighted-deficit fair scheduler state (multi-tenant decode) --
        #: tenant -> remaining deficit credits for batch-slot arbitration
        self._credits: dict[str, float] = {}
        #: tenant -> decode steps served (the fairness test's ground truth)
        self.tenant_served: dict[str, int] = {}

    def queue_depth(self) -> int:
        return (self.inbox.qsize() + len(self._stash) + self.inflight
                + sum(len(h) for h in self.held.values()))

    def executor_for(self, model: Optional[str]) -> StageExecutor:
        """The compute executor for ``model`` at this replica's stage/role.
        None or the default model hit ``self.executor`` (which may be a
        replica-private warm-bootstrap executor); other resident models
        share the server's per-(model, stage, role) executor — a session's
        cache must only ever meet the executor that owns its weights."""
        server = self.server
        if model is None or model == server.default_model:
            return self.executor
        return server.model_executor(model, self.stage, self.role)

    def install_session(self, sid: int, cache: Any, batch: int,
                        step: int, trace: Any = None,
                        model: Optional[str] = None,
                        tenant: Optional[str] = None) -> None:
        """Adopt migrated/restored decode state at a step boundary. A paged
        wire payload is installed page-by-page into this executor's pool
        (deduping against pages it already holds); anything else passes
        through unchanged."""
        ex = self.executor_for(model)
        cache = ex.adopt_cache(cache)
        old = self.sessions.pop(sid, None)
        if old is not None:
            if old.cache is not cache:
                self.executor_for(old.model).release_cache(old.cache)
            self.server.registry.release(
                self.worker_id, old.model or self.server.default_model)
        self.sessions[sid] = _Session(cache=cache, batch=batch, step=step,
                                      touched=time.monotonic(), trace=trace,
                                      model=model, tenant=tenant)
        # the session pins its model's residency here until dropped
        self.server.registry.acquire(
            self.worker_id, model or self.server.default_model)

    def drop_session(self, sid: int) -> None:
        """Forget a session AND return its stage cache to the executor —
        for a paged handle that decrements page refcounts (shared prefix
        pages survive while siblings still hold them); contiguous caches
        just lose their last reference."""
        sess = self.sessions.pop(sid, None)
        if sess is not None:
            self.executor_for(sess.model).release_cache(sess.cache)
            self.server.registry.release(
                self.worker_id, sess.model or self.server.default_model)

    def open_sessions(self) -> int:
        return len(self.sessions)

    def watch_upstream(self, world: str, router: ReplicaRouter) -> None:
        self.upstream.append(world)
        self.upstream_edges.append((world, router))
        self._pumps[world] = self.worker.spawn(self._pump(world))

    def drop_upstream(self, world: str) -> None:
        task = self._pumps.pop(world, None)
        if task is not None and not task.done():
            task.cancel()
        if world in self.upstream:
            self.upstream.remove(world)
        self.upstream_edges = [(w, r) for w, r in self.upstream_edges
                               if w != world]

    async def _pump(self, world: str) -> None:
        comm = self.worker.comm
        try:
            while True:
                payload = await comm.recv(0, world)
                await self.inbox.put((payload, time.monotonic()))
        except (WorldBrokenError, WorldNotFoundError, asyncio.CancelledError):
            return

    # ------------------------------------------------------------- serve loop
    async def run(self) -> None:
        ex = self.executor
        loop = asyncio.get_event_loop()
        while True:
            if self._stash:
                env, t_enq = self._stash.popleft()
            else:
                env, t_enq = await self.inbox.get()
            t0 = time.monotonic()
            self.wait_s_sum += t0 - t_enq
            self.inflight += 1
            try:
                await self._dispatch(ex, loop, env, t0)
            except asyncio.CancelledError:
                raise
            except (WorldBrokenError, WorldNotFoundError):
                pass   # per-send handling already rerouted or retried
            except Exception as e:  # noqa: BLE001 — a failed stage dispatch
                # must not kill the serve loop; bounce the session so the
                # client rebuilds state elsewhere. This is the flight
                # recorder's "unhandled failure" dump trigger: whatever led
                # here is a bug or a torn dependency worth a timeline.
                rec = self.server.recorder
                rec.record("unhandled_failure", worker=self.worker_id,
                           env_kind=int(env.kind), session=env.session_id,
                           error=repr(e))
                rec.dump("unhandled_failure", worker=self.worker_id)
                self.drop_session(env.session_id)
                if env.kind in (Kind.PREFILL, Kind.DECODE, Kind.VERIFY,
                                Kind.PROPOSE):
                    await self._send_retry(env)
            finally:
                self.inflight -= 1
            self._maybe_reap(t0)

    async def _dispatch(self, ex: StageExecutor, loop, env: Envelope,
                        t0: float) -> None:
        sid = env.session_id
        if env.kind in (Kind.DECODE, Kind.FINISH, Kind.VERIFY):
            target = self.migrated.get(sid)
            if target is not None:
                # session handed off after this envelope was already sent
                # toward us — forward to its new home instead of bouncing
                if env.kind is Kind.FINISH:
                    self.migrated.pop(sid, None)
                target.inbox.put_nowait((env, t0))
                return
            if sid in self.held:
                self.held[sid].append((env, t0))
                return
        if env.expired(t0):
            await self._expire(env)
            return
        kind = env.kind
        if kind in (Kind.HANDOFF, Kind.LOAD, Kind.UNLOAD, Kind.SWAP):
            # handoff/residency chunks travel dedicated pairwise worlds
            # consumed by their own receive loops (MigrationManager /
            # WarmBootstrap); one in a serve inbox is a misroute — drop it
            # rather than decode it
            return
        if kind in (Kind.SCORE, Kind.PREFILL, Kind.DECODE, Kind.VERIFY):
            name = env.model or self.server.default_model
            if name not in self.resident:
                # routed here before a swap/unload retagged the rotation —
                # bounce rather than run foreign weights
                if kind in (Kind.DECODE, Kind.PREFILL, Kind.VERIFY):
                    await self._send_retry(env)
                return
            ex = self.executor_for(env.model)
            self.server.registry.touch(self.worker_id, name)
        if kind is Kind.RETRY:
            # stateless pass-through toward the client — any healthy path
            await self._forward_routed(env)
        elif kind is Kind.FINISH:
            await self._finish_session(env)
        elif kind is Kind.SCORE:
            y = await loop.run_in_executor(None, ex.score, env.payload)
            if await self._forward_routed(
                    dataclasses.replace(env, payload=y)) is not None:
                self.processed += 1
                self.service_s_sum += time.monotonic() - t0
        elif kind is Kind.PREFILL:
            await self._handle_prefill(ex, loop, env, t0)
        elif kind is Kind.PROPOSE:
            await self._handle_propose(loop, env, t0)
        elif kind is Kind.VERIFY:
            await self._handle_verify(ex, loop, env, t0)
        else:
            await self._handle_decode(ex, loop, env, t0)

    async def _handle_prefill(self, ex: StageExecutor, loop, env: Envelope,
                              t0: float) -> None:
        if self.draining:
            await self._send_retry(env)
            return
        y, cache = await loop.run_in_executor(None, ex.prefill, env.payload)
        server = self.server
        if server._is_last(self.stage):
            y = y[:, -1]              # client only needs last-position logits
        sid = env.session_id
        batch = int(env.payload.shape[0])
        # -- decode home: where this session's stage slice will live -------
        # A 'both' replica keeps the cache (the colocated path, unchanged).
        # A prefill-pool replica streams it to a placement-ranked decode
        # peer over the statexfer chunked codec and pins the decode route
        # there; with no decode-capable peer (e.g. the only decode replica
        # just died and the heal is still in flight) it degrades to serving
        # the session locally rather than livelocking the client in RETRY.
        home: "_Replica" = self
        if self.role == ROLE_PREFILL and sid >= 0:
            peer = server._pick_decode_peer(self.stage, exclude=self,
                                            nbytes=cache_nbytes(cache),
                                            model=env.model)
            if peer is not None:
                ok = await server.migrations.handoff_prefill(
                    self, peer, sid, cache, batch, env.step,
                    trace=env.trace, model=env.model, tenant=env.tenant)
                # either way the prefill side is done with this cache: the
                # bytes are on the wire (or abandoned) — return its pages
                # to the prefill pool instead of stranding them
                ex.release_cache(cache)
                if not ok:
                    # mid-handoff failure: unwind to the at-least-once
                    # discipline — RETRY bounces the client into a full
                    # re-prefill on the prefill pool
                    await self._send_retry(env)
                    return
                home = peer
                self.handoffs_out += 1
        if home is self:
            self.sessions[sid] = _Session(
                cache=cache, batch=batch, step=env.step,
                touched=time.monotonic(), trace=env.trace,
                model=env.model, tenant=env.tenant)
            server.registry.acquire(self.worker_id,
                                    env.model or server.default_model)
        else:
            # a step routed at us before the pins stitched (or a straggler
            # in our channels) forwards in-process to the decode home
            self.migrated[sid] = home
            if server._is_last(self.stage):
                client_edge = _edge(server.name, home.worker_id, CLIENT)
                if client_edge in home.router.healthy():
                    home.router.pin(sid, client_edge)
        server._pin_upstream(self, env, home)
        world = await self._forward_routed(
            dataclasses.replace(env, payload=y, home=home.worker_id))
        if world is None:            # expired while parked — orphan reaped
            home.drop_session(sid)
            self.migrated.pop(sid, None)
            return
        if home is self and self.router.pinned(sid) is None:
            # colocated downstream pin — unless the next stage's handoff
            # already stitched the decode route onto its own decode home
            self.router.pin(sid, world)
        self.processed += 1
        dt = time.monotonic() - t0
        self.service_s_sum += dt
        self.prefill_s_sum += dt
        self.prefills += 1
        self.ttft_sketch.insert(dt)
        server.tracer.span(env.trace, "prefill", t0, self.worker_id)

    async def _handle_decode(self, ex: StageExecutor, loop, env: Envelope,
                             t0: float) -> None:
        """Continuous-batching micro-scheduler: serve this decode step fused
        with every compatible queued step (same per-session batch shape and
        model, any position), waiting up to ``microbatch_wait_s`` for
        stragglers when more sessions are open than are in hand. Batch
        slots are arbitrated across tenants by weighted deficit round-robin
        (see :meth:`_pull_compatible`)."""
        sess0 = self.sessions.get(env.session_id)
        if self.draining or sess0 is None:
            self.drop_session(env.session_id)
            await self._send_retry(env)
            return
        # the session's own model is authoritative for the executor — a
        # replayed or untagged step must never run foreign weights
        ex = self.executor_for(sess0.model)
        batch: list[Envelope] = [env]
        self.active.add(env.session_id)
        max_n = self.server.microbatch_max
        deadline = t0 + self.server.microbatch_wait_s
        try:
            while len(batch) < max_n:
                pulled = self._pull_compatible(env, max_n - len(batch), batch)
                if pulled:
                    continue
                if (len(self.sessions) <= len(batch)
                        or time.monotonic() >= deadline):
                    break
                await asyncio.sleep(0)

            # a concurrent teardown/reap may have dropped a session between
            # the compatibility check and now — bounce those, fuse the rest
            live: list[tuple[Envelope, _Session]] = []
            for e in batch:
                sess = self.sessions.get(e.session_id)
                if sess is None:
                    await self._send_retry(e)
                else:
                    live.append((e, sess))
            if not live:
                return
            try:
                outs = await loop.run_in_executor(
                    None, ex.decode_many,
                    [s.cache for _, s in live],
                    [e.payload for e, _ in live],
                    [e.step for e, _ in live])
            except Exception:  # noqa: BLE001 — a failed fused dispatch must
                # bounce EVERY coalesced session, not just the first: the
                # batch-mates were already pulled off the inbox and would
                # otherwise stall their clients a full step_timeout
                for e, _ in live:
                    self.drop_session(e.session_id)
                    await self._send_retry(e)
                return
            now = time.monotonic()
            self.decode_batches += 1
            tr = self.server.tracer
            for (e, sess), (y, new_cache) in zip(live, outs):
                sess.cache = new_cache
                sess.step = e.step
                sess.touched = now
                self.decode_steps += 1
                self.tokens_out += sess.batch
                t_name = e.tenant or "default"
                self.tenant_served[t_name] = (
                    self.tenant_served.get(t_name, 0) + 1)
                tr.span(e.trace, "decode", t0, self.worker_id)
                await self._forward_pinned(dataclasses.replace(e, payload=y))
                self.processed += 1
            dt = time.monotonic() - t0
            self.service_s_sum += dt
            self.decode_s_sum += dt
            self.decode_sketch.insert(dt)
        finally:
            # coalesced extras were pulled out of the inbox by this handler;
            # the run loop only balances the first envelope's inflight count
            self.inflight -= len(batch) - 1
            for e in batch:
                self.active.discard(e.session_id)

    async def _handle_propose(self, loop, env: Envelope, t0: float) -> None:
        """Draft side of speculative decoding. The payload is the session's
        FULL committed history (B, S): draft state is disposable by
        construction — a fresh, healed, or re-picked draft replica simply
        re-prefills the history locally, so a draft-pool kill never costs
        a single *target*-pool token. Known sessions integrate only the
        tokens committed since the last round. Replies with ``spec_k``
        greedy draft-model proposals (B, k) int32."""
        if self.draining:
            await self._send_retry(env)
            return
        ex = self.executor          # always the draft-model executor
        sid = env.session_id
        hist = jnp.asarray(env.payload, jnp.int32)
        s = int(hist.shape[1])
        bsz = int(hist.shape[0])
        # proposal i is written at slot s+i-1; clamp k so the last write
        # stays inside the draft cache (k=1 writes nothing beyond history)
        k = max(1, min(int(env.spec_k) or 1, ex.max_len - s + 1))
        sess = self.sessions.get(sid)

        def _propose():
            cache = sess.cache if sess is not None else None
            done = sess.step if sess is not None else 0
            if cache is None or done < 1 or done > s:
                # unknown/stale session: rebuild the draft cache from the
                # full history, then let the rollout re-feed the last
                # token (an exact no-op rewrite for full caches) so the
                # integrate+propose path below is the only compute shape
                _, cache = ex.prefill(hist)
                done = s - 1
            elif done >= s:
                done = s - 1    # replayed round: idempotent re-decode
            # ONE fused dispatch: integrate hist[done:] and roll out k
            # greedy proposals (see StageExecutor.propose_rollout)
            props, cache = ex.propose_rollout(cache, hist[:, done:],
                                              done, k)
            return np.asarray(props), cache

        try:
            props, cache = await loop.run_in_executor(None, _propose)
        except Exception:  # noqa: BLE001 — degrade, never fail the client
            self.drop_session(sid)
            await self._send_retry(env)
            return
        now = time.monotonic()
        if sess is not None:
            sess.cache, sess.step, sess.touched = cache, s, now
        else:
            self.sessions[sid] = _Session(
                cache=cache, batch=bsz, step=s, touched=now,
                trace=env.trace, tenant=env.tenant)
            self.server.registry.acquire(self.worker_id,
                                         self.server.default_model)
        self.spec_proposals += 1
        self.server.tracer.span(env.trace, "propose", t0, self.worker_id)
        await self._forward_routed(
            dataclasses.replace(env, payload=props, spec_k=k))
        self.processed += 1
        self.service_s_sum += time.monotonic() - t0

    async def _handle_verify(self, ex: StageExecutor, loop, env: Envelope,
                             t0: float) -> None:
        """Target side of speculative decoding: integrate the session's
        current token plus its k draft proposals in ONE fused dispatch
        (``verify_many``), coalescing compatible queued VERIFYs exactly
        like decode steps. The last stage judges the accepted prefix by
        greedy argmax — token j's logits saw precisely the verified tokens
        before it, so the committed block (accepted proposals + one bonus
        target token) is bit-identical to plain decode. Intermediate
        stages forward K hidden columns with the proposal block riding
        ``spec_tokens``."""
        sess0 = self.sessions.get(env.session_id)
        if self.draining or sess0 is None:
            self.drop_session(env.session_id)
            await self._send_retry(env)
            return
        ex = self.executor_for(sess0.model)
        batch: list[Envelope] = [env]
        self.active.add(env.session_id)
        max_n = self.server.microbatch_max
        deadline = t0 + self.server.microbatch_wait_s
        try:
            while len(batch) < max_n:
                pulled = self._pull_compatible(env, max_n - len(batch), batch)
                if pulled:
                    continue
                if (len(self.sessions) <= len(batch)
                        or time.monotonic() >= deadline):
                    break
                await asyncio.sleep(0)
            live: list[tuple[Envelope, _Session]] = []
            for e in batch:
                sess = self.sessions.get(e.session_id)
                if sess is None:
                    await self._send_retry(e)
                else:
                    live.append((e, sess))
            if not live:
                return
            try:
                outs = await loop.run_in_executor(
                    None, ex.verify_many,
                    [s.cache for _, s in live],
                    [e.payload for e, _ in live],
                    [e.step for e, _ in live])
            except Exception:  # noqa: BLE001 — bounce every coalesced
                # session, same discipline as a failed fused decode
                for e, _ in live:
                    self.drop_session(e.session_id)
                    await self._send_retry(e)
                return
            now = time.monotonic()
            self.decode_batches += 1
            last = self.server._is_last(self.stage)
            tr = self.server.tracer
            for (e, sess), (y, new_cache) in zip(live, outs):
                if last:
                    toks = np.asarray(e.spec_tokens
                                      if e.spec_tokens is not None
                                      else e.payload)
                    props = toks[:, 1:]
                    g = np.argmax(np.asarray(y), axis=-1)   # (B, K) greedy
                    k = props.shape[1]
                    m = 0
                    while m < k and bool(np.all(props[:, m] == g[:, m])):
                        m += 1
                    committed = g[:, :m + 1].astype(np.int32)
                    # roll rejected-suffix pages back before anything else
                    # can observe the handle (paged mode; contiguous no-op)
                    new_cache = ex.commit_verify(new_cache, e.step + m + 1)
                    sess.step = e.step + m
                    self.spec_verifies += 1
                    self.spec_proposed += k * sess.batch
                    self.spec_accepted += m * sess.batch
                    self.decode_steps += m + 1
                    self.tokens_out += sess.batch * (m + 1)
                    reply = dataclasses.replace(e, payload=committed,
                                                spec_tokens=None)
                else:
                    # acceptance is judged downstream; keep this stage's
                    # cursor conservative (re-integration of the accepted
                    # suffix is an idempotent rewrite for full caches)
                    sess.step = e.step
                    reply = dataclasses.replace(
                        e, payload=y,
                        spec_tokens=(e.spec_tokens
                                     if e.spec_tokens is not None
                                     else np.asarray(e.payload)))
                sess.cache = new_cache
                sess.touched = now
                t_name = e.tenant or "default"
                self.tenant_served[t_name] = (
                    self.tenant_served.get(t_name, 0) + 1)
                tr.span(e.trace, "verify", t0, self.worker_id)
                await self._forward_pinned(reply)
                self.processed += 1
            dt = time.monotonic() - t0
            self.service_s_sum += dt
            self.decode_s_sum += dt
            self.decode_sketch.insert(dt)
        finally:
            self.inflight -= len(batch) - 1
            for e in batch:
                self.active.discard(e.session_id)

    def _pull_compatible(self, proto: Envelope, n: int,
                         batch: list[Envelope]) -> int:
        """Drain queued envelopes: coalesce compatible DECODEs into ``batch``
        (counting them in-flight so drain can't observe a false empty),
        stash everything else in arrival order.

        Multi-tenant arbitration (weighted deficit round-robin): when more
        compatible steps are queued than batch slots remain, the slots are
        not first-come-first-served — each backlogged tenant holds a credit
        balance refilled in proportion to its weight
        (``server.tenant_weights``, default 1.0), one credit buys one slot,
        and the richest backlogged tenant is served first. Steps that lose
        the arbitration go to the stash, where the serve loop picks them up
        next round with their credits accrued — bounded latency for light
        tenants under a heavy tenant's flood, full batches when only one
        tenant is backlogged. A single-tenant pipeline always selects
        everything, byte-identical to the pre-tenancy scheduler."""
        in_batch = {e.session_id for e in batch}
        now = time.monotonic()
        lead = self.sessions.get(proto.session_id)
        lead_model = lead.model if lead is not None else proto.model
        #: tenant -> compatible candidates, arrival order preserved
        cands: dict[str, deque] = {}
        while True:
            try:
                item = self.inbox.get_nowait()
            except asyncio.QueueEmpty:
                break
            env, t_enq = item
            sess = self.sessions.get(env.session_id)
            if (env.kind is proto.kind and sess is not None
                    and env.session_id not in self.held
                    and env.session_id not in self.migrated
                    and sess.model == lead_model
                    and env.payload.shape == proto.payload.shape
                    and not env.expired(now)):
                cands.setdefault(env.tenant or "default",
                                 deque()).append(item)
            else:
                self._stash.append(item)
        pulled = 0
        weights = self.server.tenant_weights
        cap = float(self.server.microbatch_max)
        while pulled < n and any(cands.values()):
            backlogged = [t for t, q in cands.items() if q]
            pick = max(backlogged, key=lambda t: self._credits.get(t, 0.0))
            if self._credits.get(pick, 0.0) < 1.0:
                # deficit round: every *backlogged* tenant earns its
                # weight (idle tenants accrue nothing — no stale credit
                # stockpiles), capped at one full batch worth
                for t in backlogged:
                    w = float(weights.get(t, 1.0))
                    self._credits[t] = min(
                        self._credits.get(t, 0.0) + w, w * cap)
                pick = max(backlogged,
                           key=lambda t: self._credits.get(t, 0.0))
            env, t_enq = cands[pick].popleft()
            if env.session_id in in_batch:
                # a session already has a step in hand; its duplicate
                # waits for the next round
                self._stash.append((env, t_enq))
                continue
            self._credits[pick] = self._credits.get(pick, 0.0) - 1.0
            self.wait_s_sum += time.monotonic() - t_enq
            self.inflight += 1
            batch.append(env)
            in_batch.add(env.session_id)
            self.active.add(env.session_id)
            pulled += 1
        # arbitration losers go back in front of future inbox work
        for q in cands.values():
            self._stash.extend(q)
        return pulled

    # ------------------------------------------------------------ forwarding
    async def _forward_routed(self, env: Envelope) -> Optional[str]:
        """Send via the rotation (SCORE/PREFILL/RETRY). Parks on an empty
        rotation until the controller heals a downstream replica; drops the
        envelope if its deadline passes while parked. PREFILL/SCORE honor
        the envelope's role tag, so a split downstream stage receives them
        in its prefill pool — and its model tag, so a multi-model stage
        receives them on a replica with the model resident. Returns the
        world used (None if dropped)."""
        comm = self.worker.comm
        fwd = env.kind in (Kind.PREFILL, Kind.SCORE)
        role = env.role if fwd else None
        model = env.model if fwd else None
        while True:
            if env.expired(time.monotonic()):
                self.expired += 1
                return None
            world = self.router.try_pick(
                least_loaded=self.server.least_loaded, role=role,
                model=model)
            if world is None:
                # Every routable downstream world is gone. Dying here would
                # drop the in-flight payload and kill this serve loop for
                # good — park instead and retry once the controller
                # adds/heals a downstream replica.
                self.parked += 1
                if ((role is not None or model is not None)
                        and self.router.healthy()):
                    # worlds exist, just none role/model-capable: the
                    # controller is growing that pool (or a load/swap is in
                    # flight) — the any-world event is already set, so poll
                    # instead of waiting on it
                    await asyncio.sleep(0.005)
                else:
                    await self.router.wait_healthy()
                continue
            try:
                await comm.send(env, 1, world)
                return world
            except WorldBrokenError:
                self.router.mark_broken(world)
            except WorldNotFoundError:
                self.router.remove(world)

    async def _forward_pinned(self, env: Envelope) -> None:
        """Send a decode result along the session's pinned route; if the pin
        is gone (downstream death, drain, or fencing), bounce the session
        back to the client — but keep the *local* stage slice: this stage's
        cache is still consistent, and the client's restore path (racing
        the controller's live heal of the downstream stage) rebuilds the
        route from exactly this state with zero recompute. If the client
        instead gives up and re-prefills, it sweeps the partial route with
        a FINISH; the TTL reap is the backstop."""
        world = self.router.pinned(env.session_id)
        if world is None:
            await self._send_retry(env)
            return
        try:
            await self.worker.comm.send(env, 1, world)
        except WorldBrokenError:
            self.router.mark_broken(world)
            await self._send_retry(env)
        except WorldNotFoundError:
            self.router.remove(world)
            await self._send_retry(env)

    async def _expire(self, env: Envelope) -> None:
        """Deadline enforcement at the stage boundary: the client has given
        up on this step, so burn no compute on it — drop local session
        state and propagate FINISH(error) toward the client (cleaning up
        downstream stage state on the way) instead of silently eating it."""
        self.expired += 1
        self.server.recorder.record(
            "deadline_expired", worker=self.worker_id,
            session=env.session_id, step=env.step)
        if (env.kind not in (Kind.PREFILL, Kind.DECODE, Kind.VERIFY)
                or env.session_id < 0):
            return
        self.drop_session(env.session_id)
        fin = Envelope(req_id=env.req_id, session_id=env.session_id,
                       kind=Kind.FINISH, step=env.step,
                       error=f"deadline exceeded at {self.worker_id} "
                             f"(step {env.step})", trace=env.trace)
        world = self.router.pinned(env.session_id)
        self.router.unpin(env.session_id)
        if world is not None:
            try:
                await self.worker.comm.send(fin, 1, world)
                return
            except (WorldBrokenError, WorldNotFoundError):
                pass
        await self._forward_routed(fin)

    async def _send_retry(self, env: Envelope) -> None:
        self.retries_sent += 1
        self.router.unpin(env.session_id)
        await self._forward_routed(Envelope(
            req_id=env.req_id, session_id=env.session_id, kind=Kind.RETRY,
            step=env.step, trace=env.trace))

    async def _finish_session(self, env: Envelope) -> None:
        self.drop_session(env.session_id)
        if self.server._is_last(self.stage):
            self.server.session_margins.pop(env.session_id, None)
        world = self.router.pinned(env.session_id)
        self.router.unpin(env.session_id)
        if env.error is not None:
            # server-initiated FINISH (deadline drop): must reach the client,
            # not stop at the last stage like a client FINISH does — route it
            # on even when this stage never pinned the session
            if world is not None:
                try:
                    await self.worker.comm.send(env, 1, world)
                    return
                except (WorldBrokenError, WorldNotFoundError):
                    pass
            await self._forward_routed(env)
            return
        if world is None or self.server._is_last(self.stage):
            return
        try:
            # best-effort: a lost FINISH only delays reaping to the TTL sweep
            await self.worker.comm.send(env, 1, world)
        except (WorldBrokenError, WorldNotFoundError):
            pass

    def _maybe_reap(self, now: float) -> None:
        """Drop session state orphaned by lost FINISHes or dead clients."""
        if now - self._last_reap < 1.0:
            return
        self._last_reap = now
        ttl = self.server.session_ttl_s
        for sid in [s for s, sess in self.sessions.items()
                    if now - sess.touched > ttl]:
            self.drop_session(sid)
            self.router.unpin(sid)
            if self.server._is_last(self.stage):
                self.server.session_margins.pop(sid, None)
        # forwarding stubs for handed-off sessions: once the decode home no
        # longer holds the session (FINISHed/reaped/moved on), the stub is
        # garbage — a long-lived prefill replica would otherwise keep one
        # per prefill it ever served
        for sid in [s for s, tgt in self.migrated.items()
                    if s not in tgt.sessions and s not in tgt.held
                    and s not in tgt.migrated]:
            del self.migrated[sid]

    async def reap_loop(self) -> None:
        """Periodic TTL sweep: an *idle* replica (rerouted traffic, fenced
        upstream) never re-enters run()'s dispatch path, so without this its
        orphaned per-session KV caches would be held forever."""
        try:
            while True:
                await asyncio.sleep(1.0)
                self._maybe_reap(time.monotonic())
        except asyncio.CancelledError:
            return


class PipelineServer:
    """Build/serve/heal a replicated stage pipeline on a MultiWorld cluster."""

    def __init__(self, cluster: Cluster, model, params,
                 replicas: list, *, name: str = "pipe",
                 least_loaded: bool = False, max_len: int = 256,
                 paged: bool = False, page_size: int = 16,
                 pool_pages: Optional[int] = None,
                 microbatch_max: int = 8, microbatch_wait_s: float = 0.002,
                 session_ttl_s: float = 60.0,
                 snapshot_interval_s: Optional[float] = None,
                 snapshot_codec: str = "fp",
                 restore_grace_s: float = 0.5,
                 tracing: bool = True,
                 trace_capacity: int = 32768,
                 trace_sample_rate: float = 1.0,
                 trace_slow_keep_s: Optional[float] = None,
                 flightrec_capacity: int = 4096,
                 dump_dir: Optional[str] = None,
                 registry: Optional[ModelRegistry] = None,
                 default_model: str = "default",
                 max_resident_models: Optional[int] = None,
                 tenant_weights: Optional[dict] = None,
                 draft_model=None, draft_params=None,
                 spec_k: int = 4) -> None:
        self.cluster = cluster
        self.model = model
        self.cfg = model.cfg
        self.name = name
        #: which models this pool can serve and where they are resident —
        #: the (model, params) passed above is registered as
        #: ``default_model``; further models join via ``register_model`` +
        #: ``load_model``/``swap_model``. A shared registry may be passed
        #: in (several pipelines on one model store).
        self.default_model = default_model
        self.registry = registry or ModelRegistry(
            max_resident=max_resident_models)
        if default_model not in self.registry.entries:
            self.registry.register(default_model, model, params)
        #: tenant -> weight for the decode micro-scheduler's weighted
        #: deficit round-robin; unlisted tenants weigh 1.0
        self.tenant_weights: dict[str, float] = dict(tenant_weights or {})
        #: sid -> model / tenant of every client-side open session (the
        #: restore path and per-tenant accounting read these; single-model
        #: untagged sessions never enter them)
        self.session_models: dict[int, str] = {}
        self.session_tenants: dict[int, str] = {}
        #: client-observed per-tenant latency distributions + counters,
        #: folded into MetricsHub tenant tails each poll
        self.tenant_sketches: dict[str, dict[str, LogSketch]] = {}
        self.tenant_tokens: dict[str, int] = {}
        self.tenant_sessions: dict[str, int] = {}
        #: completed residency swaps (controller-driven model A -> B)
        self.swaps_total = 0
        # replica spec per stage: an int builds that many colocated
        # ('both') replicas — the pre-disaggregation behavior, unchanged —
        # while {"prefill": p, "decode": d} splits the stage into
        # role-specialized pools
        self.replica_roles: list[dict[str, int]] = []
        # -- speculative decoding (draft role) -----------------------------
        #: the small proposer model served by ``draft``-role replicas, and
        #: the default k-token proposal budget per round (``generate``'s
        #: ``spec_k=`` overrides per call; 0 disables speculation). With no
        #: draft model the pipeline never speculates, bit-identical to the
        #: pre-draft behavior.
        self.draft_model = draft_model
        self.draft_params = draft_params
        self.spec_k = int(spec_k) if draft_model is not None else 0
        #: client-side speculation totals (MetricsHub's ``spec`` group)
        self.spec_fallbacks_total = 0   # rounds degraded to plain decode
        self.spec_rounds_total = 0      # verify round-trips completed
        self.spec_proposed_total = 0    # draft tokens sent to verification
        self.spec_accepted_total = 0    # draft tokens verification accepted
        for spec in replicas:
            if isinstance(spec, dict):
                roles = {r: int(n) for r, n in spec.items() if int(n) > 0}
                bad = set(roles) - {ROLE_BOTH, ROLE_PREFILL, ROLE_DECODE,
                                    ROLE_DRAFT}
                if bad:
                    raise ValueError(f"unknown replica roles {sorted(bad)}")
                if ROLE_DRAFT in roles and draft_model is None:
                    raise ValueError(
                        "draft replicas need draft_model/draft_params")
                if not any(r in (ROLE_BOTH, ROLE_PREFILL) for r in roles):
                    # a decode-only stage could never serve a PREFILL: the
                    # role-aware rotation would park every new session
                    raise ValueError(
                        "stage needs at least one prefill-capable "
                        f"(prefill/both) replica: {roles}")
                self.replica_roles.append(roles)
            else:
                self.replica_roles.append({ROLE_BOTH: int(spec)})
        self.replica_counts = [sum(r.values()) for r in self.replica_roles]
        self.n_stages = len(replicas)
        self.least_loaded = least_loaded
        self.max_len = max_len
        #: paged KV mode: every stage executor allocates its cache out of a
        #: PagePool (shared prompt-prefix pages, page-granular state
        #: transfer) instead of per-session contiguous buffers
        self.paged = paged
        self.page_size = page_size
        self.pool_pages = pool_pages
        #: continuous-batching knobs: how many decode steps one dispatch may
        #: fuse, and how long to hold the first step for stragglers
        self.microbatch_max = microbatch_max
        self.microbatch_wait_s = microbatch_wait_s
        self.session_ttl_s = session_ttl_s
        #: how long a bounced client keeps retrying the cheap restore path
        #: while an alive-but-fenced replica still holds its session live —
        #: the controller's live heal is racing to move that state to a
        #: survivor, and waiting a few control ticks costs far less than
        #: recomputing the whole history
        self.restore_grace_s = restore_grace_s
        self.stage_specs = split_stages(self.cfg, self.n_stages)
        self.stage_param_sets = [stage_params(self.cfg, params, s)
                                 for s in self.stage_specs]
        #: one executor per stage, shared by the stage's replicas so they
        #: share one jit cache (compile once, serve everywhere)
        self.stage_executors = [
            StageExecutor(self.cfg, spec, sp, max_len=max_len,
                          paged=paged, page_size=page_size,
                          pool_pages=pool_pages)
            for spec, sp in zip(self.stage_specs, self.stage_param_sets)]
        #: role-specialized executors, created lazily per (stage, role) and
        #: shared within the pool — a split pool must NOT share the 'both'
        #: executor's jit cache, or "prefill replicas skip decode-bucket
        #: compiles" would be vacuously true
        self._role_executors: dict[tuple[int, str], StageExecutor] = {}
        #: non-default registered models: executors keyed per
        #: (model, stage, role) — compile caches and KV pools must never
        #: mix models — and per-model stage splits, both built lazily on
        #: first load and shared by every replica hosting the model
        self._model_executors: dict[tuple[str, int, str], StageExecutor] = {}
        self._model_stages: dict[str, tuple[list, list]] = {}
        self.instantiator = OnlineInstantiator(cluster)
        #: state-transfer subsystem: live handoff + restore, background
        #: snapshots (opt-in via snapshot_interval_s), warm scale-up
        self.migrations = MigrationManager(self)
        self.snapshots: Optional[SnapshotStore] = (
            SnapshotStore(self, interval_s=snapshot_interval_s,
                          codec=snapshot_codec)
            if snapshot_interval_s is not None else None)
        self.bootstrap = WarmBootstrap(self)
        self.replicas: list[list[_Replica]] = [[] for _ in replicas]
        self.client = cluster.worker(CLIENT)
        self.client_router = ReplicaRouter()   # worlds to stage-0 replicas
        self.client_router.set_load_probe(self._edge_load)
        self.client_router.set_drop_listener(self._forget_edge)
        self._responses: dict[int, asyncio.Future] = {}
        #: req_id -> entry world an in-flight round-trip was sent on, so a
        #: world-break fails the waiter immediately instead of letting it
        #: sit out the full step timeout (during which an otherwise-healthy
        #: session idles toward the TTL reap)
        self._response_worlds: dict[int, str] = {}
        self._req_ids = itertools.count()
        self._session_ids = itertools.count(1)
        self._uid = itertools.count()
        self._collectors: dict[str, asyncio.Task] = {}
        #: downstream edge world -> receiving replica (load probing, drain)
        self._world_to_replica: dict[str, _Replica] = {}
        #: worlds the watchdog has fenced anywhere in the pipeline
        self.broken_worlds: set[str] = set()
        #: (t, kind, detail) scale/heal/drain timeline for Fig.5-style plots
        self.events: list[tuple[float, str, str]] = []
        #: causal span tracer — default-ON; ``tracing=False`` is the A/B
        #: baseline the generate bench's overhead gate measures against.
        #: ``trace_sample_rate < 1`` head-samples session roots with
        #: tail-based keep rules (errors/heals/retries/slow outliers always
        #: survive) so tracing cost stays flat at fleet session counts
        self.tracer = Tracer(trace_capacity, enabled=tracing,
                             sample_rate=trace_sample_rate,
                             slow_keep_s=trace_slow_keep_s)
        #: flight recorder: bounded ring of structured control-plane events,
        #: dumped to JSON (under ``dump_dir`` when set) on any unhandled
        #: failure, every heal, or an explicit ``recorder.dump()``
        self.recorder = FlightRecorder(flightrec_capacity, name=name,
                                       dump_dir=dump_dir)
        # pool pressure events (page_alloc_failure) land in the flight
        # recorder's timeline next to the heals/drains they may explain
        for _ex in self.stage_executors:
            _ex.on_event = self.recorder.record
        #: deadline drops carried over from retired replicas — folded in at
        #: teardown so cumulative counters survive scale-down exactly
        self.expired_retired = 0
        #: sid -> running-min relative argmax gap observed at the last
        #: stage; the int8 snapshot path reads this to decide, per session,
        #: whether quantization noise could flip a greedy token
        self.session_margins: dict[int, float] = {}
        #: client-observed per-kind latencies, drained by MetricsHub into
        #: the TTFT / per-token-decode EWMAs the per-role policies consume
        self.ttft_log: list[float] = []
        self.decode_lat_log: list[float] = []
        self._wired_managers: set[str] = set()
        self._wire_manager(self.client.manager, self.client_router)

    def _is_last(self, stage: int) -> bool:
        return stage == self.n_stages - 1

    # ------------------------------------------------------- int8 margins
    def _margins_wanted(self) -> bool:
        """Track per-session argmax gaps only when an int8 state path can
        consume them — the partition over the vocab axis is cheap but not
        free, and fp snapshots never look at it."""
        return ((self.snapshots is not None
                 and self.snapshots.codec == INT8)
                or self.migrations.codec == INT8)

    def _note_margin(self, sid: int, logits: np.ndarray) -> None:
        """Fold one step's logits into the session's running-min relative
        argmax gap (the int8 codec's parity-margin signal). Called on the
        *client* path, which has already materialized the last-stage logits
        host-side for its own argmax — tracking here costs one extra O(V)
        partition per token and keeps the replicas' serve loops free of
        device syncs."""
        if sid < 0 or not self._margins_wanted():
            return
        m = argmax_margin(logits)
        old = self.session_margins.get(sid)
        self.session_margins[sid] = m if old is None else min(old, m)

    @staticmethod
    def _note_latency(log: list, dt: float) -> None:
        """Append one client-observed latency sample; the logs are drained
        by MetricsHub each poll, so cap the tail for hub-less runs."""
        log.append(dt)
        if len(log) > 4096:
            del log[:2048]

    def _note_tenant(self, tenant: Optional[str], kind: str,
                     dt: float) -> None:
        """Fold one client-observed latency into the tenant's mergeable
        sketch (``kind`` is 'ttft' or 'decode') — the per-tenant SLO
        policies read tails from these, and sketches survive aggregation
        where means cannot. Untagged traffic records nothing."""
        if tenant is None:
            return
        sk = self.tenant_sketches.setdefault(
            tenant, {"ttft": LogSketch(), "decode": LogSketch()})
        sk[kind].insert(dt)

    def role_executor(self, stage: int, role: str = ROLE_BOTH
                      ) -> StageExecutor:
        """The pool executor for (stage, role): the stage-shared one for
        'both' (unchanged behavior), a lazily built role-specialized one —
        own jit cache, role-filtered warm replay — for split pools."""
        if role == ROLE_BOTH:
            return self.stage_executors[stage]
        key = (stage, role)
        ex = self._role_executors.get(key)
        if ex is None:
            if role == ROLE_DRAFT:
                # the whole draft model as one stage: draft replicas talk
                # only to the client, never to pipeline peers, so there is
                # no stage split to share — and no paged pool (draft
                # caches are throwaway contiguous buffers)
                if self.draft_model is None:
                    raise ValueError(
                        "draft role requires draft_model/draft_params")
                ex = StageExecutor.for_model(
                    self.draft_model, self.draft_params,
                    max_len=self.max_len, role=ROLE_DRAFT)
            else:
                ex = StageExecutor(self.cfg, self.stage_specs[stage],
                                   self.stage_param_sets[stage],
                                   max_len=self.max_len, role=role,
                                   paged=self.paged,
                                   page_size=self.page_size,
                                   pool_pages=self.pool_pages)
            ex.on_event = self.recorder.record
            self._role_executors[key] = ex
        return ex

    # ------------------------------------------------------- model registry
    def register_model(self, name: str, model, params) -> None:
        """Make another model servable by this pool (registry store entry;
        no replica hosts it until ``load_model``/``swap_model``)."""
        self.registry.register(name, model, params)
        self._model_stages.pop(name, None)

    def model_stages(self, name: str) -> tuple[list, list]:
        """(stage_specs, stage_param_sets) of a registered model under this
        pipeline's stage split — each model partitions its own layer count
        over the same number of stages the pool runs."""
        cached = self._model_stages.get(name)
        if cached is not None:
            return cached
        if name == self.default_model:
            out = (self.stage_specs, self.stage_param_sets)
        else:
            entry = self.registry.get(name)
            specs = split_stages(entry.cfg, self.n_stages)
            out = (specs, [stage_params(entry.cfg, entry.params, s)
                           for s in specs])
        self._model_stages[name] = out
        return out

    def model_executor(self, name: str, stage: int,
                       role: str = ROLE_BOTH) -> StageExecutor:
        """The shared executor for a non-default model at (stage, role) —
        its own jit cache and KV pool, lazily built from the registry
        store's stage slice."""
        if name == self.default_model:
            return self.role_executor(stage, role)
        key = (name, stage, role)
        ex = self._model_executors.get(key)
        if ex is None:
            entry = self.registry.get(name)
            specs, psets = self.model_stages(name)
            ex = StageExecutor(entry.cfg, specs[stage], psets[stage],
                               max_len=self.max_len, role=role,
                               paged=self.paged, page_size=self.page_size,
                               pool_pages=self.pool_pages)
            ex.on_event = self.recorder.record
            self._model_executors[key] = ex
        return ex

    def _edge_load(self, world: str) -> float:
        """Router load probe: queue depth of the replica behind an edge.
        A fenced, retired, dead, or draining replica scores infinite — the
        probe must never make a world it cannot serve look least loaded
        (client edges have no replica mapping and score neutral)."""
        rep = self._world_to_replica.get(world)
        if rep is None:
            return 0.0
        if (world in self.broken_worlds or not rep.worker.alive
                or rep.draining or rep not in self.replicas[rep.stage]):
            return float("inf")
        return float(rep.queue_depth())

    def _forget_edge(self, world: str) -> None:
        """Drop-listener for every router: a world gracefully retired from
        a rotation loses its replica mapping at once, so no stale probe
        target outlives the retirement (the load-probe prune)."""
        self._world_to_replica.pop(world, None)

    def decode_replicas(self, stage: int, exclude=None,
                        model: Optional[str] = None) -> list["_Replica"]:
        """Replicas able to hold and serve decode state at ``stage`` —
        with ``model=``, only those hosting that model's weights."""
        name = model or None
        return [r for r in self.replicas[stage]
                if r is not exclude and r.worker.alive and not r.draining
                and r.role not in (ROLE_PREFILL, ROLE_DRAFT)
                and (name is None or name in r.resident)]

    def _pick_decode_peer(self, stage: int, exclude: "_Replica",
                          nbytes: int,
                          model: Optional[str] = None
                          ) -> Optional["_Replica"]:
        """The decode-pool home for a freshly prefilled session: ranked by
        (queue load + placement cost of the KV bytes about to move), the
        same ranking every other state-moving chooser uses."""
        peers = self.decode_replicas(stage, exclude=exclude, model=model)
        if not peers:
            return None
        return self.migrations._rank(exclude.worker_id, peers, nbytes)

    def _replica_by_id(self, worker_id: Optional[str],
                       stage: Optional[int] = None) -> Optional["_Replica"]:
        if worker_id is None:
            return None
        stages = [stage] if stage is not None else range(self.n_stages)
        for si in stages:
            for rep in self.replicas[si]:
                if rep.worker_id == worker_id:
                    return rep
        return None

    def _pin_upstream(self, receiver: "_Replica", env: Envelope,
                      home: "_Replica") -> None:
        """Stitch the decode route pool-to-pool during the PREFILL pass:
        the upstream stage's decode home (or the client) pins this session
        onto ``home``'s edge — not onto the prefill replica that merely
        built the cache. For colocated ('both') hops this pins exactly the
        edge the PREFILL travelled on, so the wiring is identical to the
        pre-disaggregation pins; races lose to the ``migrated`` in-process
        forwarding stub, never to a stuck session."""
        sid = env.session_id
        if sid < 0:
            return
        if receiver.stage == 0:
            router, src = self.client_router, CLIENT
        else:
            up = self._replica_by_id(env.home, stage=receiver.stage - 1)
            if up is None:
                return   # upstream home already gone; restore path covers it
            router, src = up.router, up.worker_id
        edge = _edge(self.name, src, home.worker_id)
        if edge in router.healthy():
            router.pin(sid, edge)

    def _event(self, kind: str, detail: str) -> None:
        self.events.append((time.monotonic(), kind, detail))
        # long-lived servers must not grow the timeline forever (the plots
        # only ever read the recent window); the flight recorder keeps the
        # same events in its own bounded ring for crash dumps
        if len(self.events) > 8192:
            del self.events[:4096]
        self.recorder.record(kind, detail=detail)

    # ------------------------------------------------------------------ build
    async def start(self) -> None:
        for si, roles in enumerate(self.replica_roles):
            for role, count in roles.items():
                for _ in range(count):
                    await self.add_replica(si, role=role)
        if self.snapshots is not None:
            # ride on the client worker so Cluster.shutdown reaps the task
            self.snapshots.start(spawn=self.client.spawn)

    def _wire_manager(self, manager, router: Optional[ReplicaRouter]) -> None:
        """Fault listeners: fenced worlds leave the router rotation (dropping
        any session pins) and are recorded in ``broken_worlds`` (the
        controller's failure signal)."""
        if manager.worker_id in self._wired_managers:
            return
        self._wired_managers.add(manager.worker_id)

        def cb(world: str, reason: str) -> None:
            if router is not None:
                router.mark_broken(world)
            self.broken_worlds.add(world)
            # poison in-flight client round-trips on the fenced world: the
            # reply will never come, and waiting out the step timeout can
            # cost more than the failure itself (the session's other state
            # idles toward the TTL reap meanwhile)
            for rid, sent in list(self._response_worlds.items()):
                if sent != world:
                    continue
                fut = self._responses.get(rid)
                if fut is not None and not fut.done():
                    fut.set_exception(WorldBrokenError(world))
            self._event("world_broken", world)

        manager.on_world_broken(cb)

        def world_ev(t: float, kind: str, world: str) -> None:
            # world lifecycle into the flight recorder: create ("init_done")
            # and remove, per endpoint manager. Fencing ("broken") is
            # already recorded via the break listener above.
            if kind in ("init_done", "removed"):
                self.recorder.record(f"world_{kind}", world=world,
                                     worker=manager.worker_id)

        manager.on_event(world_ev)

    async def add_replica(self, stage: int, *, role: str = ROLE_BOTH,
                          warm: bool = False,
                          fresh_executor: bool = False,
                          near: Optional[str] = None,
                          host: Optional[str] = None,
                          models: Optional[list] = None) -> str:
        """Online instantiation of one replica (paper Fig. 2c / §4.2).

        ``role`` selects the pool the replica joins: ``both`` (colocated
        default), ``prefill``, or ``decode``. The role decides which pool
        executor it shares, how upstream routers may route to it, and which
        slice of a peer's shape profile a warm bootstrap replays.

        ``warm=True`` runs the WarmBootstrap first: stage weights are
        fetched from a peer replica over the wire and the peer's served
        shape profile is pre-compiled, all before the replica enters any
        routing rotation — so its first real request hits warm caches.
        ``fresh_executor=True`` additionally gives it its own
        :class:`StageExecutor` (a new worker process would not share the
        peers' jit cache; this models that).

        Placement: ``host=`` pins the new worker to a topology host
        explicitly; ``near=`` places it on another worker's host (the heal
        path passes the failed replica, so its migrated state stays
        on-host); otherwise the topology's placement policy decides. The
        worker is placed *before* the warm bootstrap so the peer choice can
        price the weight bytes it is about to move.

        ``models=`` pre-loads registered non-default models onto the new
        replica (the heal path passes the victim's resident set, so a
        replacement hosts exactly what the dead replica did); each is
        streamed over the LOAD protocol from a resident peer once the
        replica is wired.
        """
        tag = "" if role == ROLE_BOTH else f"{role}-"
        worker_id = f"{self.name}-s{stage}-{tag}r{next(self._uid)}"
        if host is not None:
            self.cluster.topology.place_on(worker_id, host)
        self.cluster.worker(worker_id, near=near)
        rep = _Replica(self, worker_id, stage, role=role)
        self.registry.load(worker_id, self.default_model)
        if role == ROLE_DRAFT:
            # Draft replicas are a client-facing proposer pool, not a
            # pipeline stage: they run the whole draft model against the
            # session's committed history, so they need exactly one
            # client->replica edge (PROPOSE in) and one replica->client
            # edge (proposals out) — no stage peers, no handoff, no warm
            # bootstrap (there is no same-weights pipeline peer to fetch
            # from, and the first prefill compiles the one shape needed).
            w_in = _edge(self.name, CLIENT, worker_id)
            w_out = _edge(self.name, worker_id, CLIENT)
            await self.instantiator.instantiate([
                WorldSpec.pair(w_in, CLIENT, worker_id),
                WorldSpec.pair(w_out, worker_id, CLIENT)])
            rep.watch_upstream(w_in, self.client_router)
            self._world_to_replica[w_in] = rep
            self.client_router.add(w_in, role=ROLE_DRAFT,
                                   models=rep.resident)
            rep.router.add(w_out, role=ROLE_BOTH)
            self._watch_client_world(w_out)
            self._wire_manager(rep.worker.manager, rep.router)
            rep._run_task = rep.worker.spawn(rep.run())
            rep._reap_task = rep.worker.spawn(rep.reap_loop())
            self.replicas[stage].append(rep)
            self._event("add_replica", worker_id)
            return worker_id
        if warm:
            report = await self.bootstrap.bootstrap(
                stage, worker_id, fresh_executor=fresh_executor, role=role)
            rep.executor = report["executor"]
            self._event("warm_bootstrap",
                        f"{worker_id} <- {report['peer']} "
                        f"({report['bytes']}B, warm {report['warm_s']:.3f}s)")
        specs: list[WorldSpec] = []
        #: (world, router to register it in, peer replica or None for client)
        upstream_edges: list[tuple[str, ReplicaRouter, Optional[_Replica]]] = []
        down_watchers: list[tuple[str, Optional[_Replica]]] = []

        if stage == 0:
            w = _edge(self.name, CLIENT, worker_id)
            specs.append(WorldSpec.pair(w, CLIENT, worker_id))
            upstream_edges.append((w, self.client_router, None))
        else:
            for up in self.replicas[stage - 1]:
                if (not up.worker.alive or up.draining
                        or up.role == ROLE_DRAFT):
                    continue
                w = _edge(self.name, up.worker_id, worker_id)
                specs.append(WorldSpec.pair(w, up.worker_id, worker_id))
                upstream_edges.append((w, up.router, up))
        if stage == self.n_stages - 1:
            w = _edge(self.name, worker_id, CLIENT)
            specs.append(WorldSpec.pair(w, worker_id, CLIENT))
            down_watchers.append((w, None))
        else:
            for down in self.replicas[stage + 1]:
                if (not down.worker.alive or down.draining
                        or down.role == ROLE_DRAFT):
                    continue
                w = _edge(self.name, worker_id, down.worker_id)
                specs.append(WorldSpec.pair(w, worker_id, down.worker_id))
                down_watchers.append((w, down))

        await self.instantiator.instantiate(specs)

        # A peer snapshotted above may have been drained/healed away while
        # the rendezvous was in flight — wiring it now would route payloads
        # into a torn-down replica. Re-check and discard the fresh world
        # instead (None peer = the client, which never goes away).
        def _gone(peer: Optional[_Replica], adjacent: list[_Replica]) -> bool:
            return peer is not None and (peer not in adjacent
                                         or not peer.worker.alive
                                         or peer.draining)

        for world, router, up in upstream_edges:
            if _gone(up, self.replicas[stage - 1] if stage else []):
                self._remove_world_everywhere(world)
                continue
            rep.watch_upstream(world, router)
            self._world_to_replica[world] = rep
            # the rotation learns the receiver's role and resident models,
            # so PREFILLs can be steered into the prefill pool and onto a
            # replica that hosts their model
            router.add(world, role=rep.role, models=rep.resident)
        for world, down in down_watchers:
            if _gone(down, self.replicas[stage + 1]
                     if stage < self.n_stages - 1 else []):
                self._remove_world_everywhere(world)
                continue
            rep.router.add(world,
                           role=ROLE_BOTH if down is None else down.role,
                           models=None if down is None else down.resident)
            if down is None:
                self._watch_client_world(world)
            else:
                down.watch_upstream(world, rep.router)
                self._world_to_replica[world] = down

        # replica-side fault listener: broken downstream worlds leave rotation
        self._wire_manager(rep.worker.manager, rep.router)

        rep._run_task = rep.worker.spawn(rep.run())
        rep._reap_task = rep.worker.spawn(rep.reap_loop())
        self.replicas[stage].append(rep)
        # non-default residency (the heal path restores the victim's set):
        # streamed over the LOAD protocol now that the replica is wired
        for m in dict.fromkeys(models or ()):
            if m != self.default_model:
                await self.load_model(worker_id, m, warm=warm)
        self._event("add_replica", worker_id)
        return worker_id

    # ----------------------------------------------------- model residency
    def _retag_replica(self, rep: _Replica) -> None:
        """Push a replica's current resident set onto every upstream
        rotation edge — the routing side of a residency change, applied
        the instant the registry flips so no pick can land a model on a
        replica that no longer (or does not yet) host it."""
        for world, router in rep.upstream_edges:
            router.set_models(world, rep.resident)

    async def load_model(self, worker_id: str, name: str, *,
                         warm: bool = True) -> dict:
        """Hot-load a registered model onto a live replica without it ever
        leaving rotation: stage weights stream from a same-stage resident
        peer as LOAD envelopes (cold from the registry store when no peer
        hosts the model), the registry marks residency (LRU-evicting
        refcount-zero models past ``max_resident_models``), and every
        upstream rotation retags. Returns the bootstrap report."""
        rep = self._replica_by_id(worker_id)
        if rep is None:
            raise KeyError(f"no replica {worker_id}")
        self.registry.get(name)
        if name in rep.resident:
            return {"source": "resident", "bytes": 0, "peer": None}
        report = await self.bootstrap.load_model(rep, name, warm=warm)
        evicted = self.registry.load(worker_id, name)
        for m in evicted:
            rep.resident.discard(m)
            self._event("model_evict", f"{worker_id} -= {m} (LRU)")
        rep.resident.add(name)
        self._retag_replica(rep)
        self._event("model_load",
                    f"{worker_id} += {name} [{report['source']}] "
                    f"({report['bytes']}B)")
        return report

    async def unload_model(self, worker_id: str, name: str, *,
                           force: bool = False,
                           migrate: bool = True) -> None:
        """Retire a model's residency on one replica. Open sessions of that
        model are first live-migrated to another resident replica
        (``migrate=True``); whatever cannot move is dropped so its client
        re-prefills on a capable survivor — unless the registry refuses
        (sessions still pinned and ``force=False``). The default model
        cannot be unloaded (it is the pipeline's identity)."""
        if name == self.default_model:
            raise ResidencyError(
                f"cannot unload the pipeline's default model {name!r}")
        rep = self._replica_by_id(worker_id)
        if rep is None:
            raise KeyError(f"no replica {worker_id}")
        if name not in rep.resident:
            return
        sids = [sid for sid, sess in rep.sessions.items()
                if (sess.model or self.default_model) == name]
        if sids and migrate:
            for sid in sids:
                await self.migrations.migrate_session(rep, sid)
        # stragglers (migration failed / raced in): bounce to re-prefill —
        # client-invisible at-least-once recovery, not a failure
        for sid in [s for s in sids if s in rep.sessions]:
            rep.drop_session(sid)
        self.registry.unload(worker_id, name, force=force)
        rep.resident.discard(name)
        self._retag_replica(rep)
        self._event("model_unload", f"{worker_id} -= {name}")

    async def swap_model(self, worker_id: str, from_name: str,
                         to_name: str, *, warm: bool = True) -> dict:
        """Swap one replica's residency ``from_name`` -> ``to_name`` under
        traffic: stream the incoming model in (SWAP-headed LOAD stream with
        an UNLOAD trailer on the wire), migrate the incumbent model's open
        sessions to other resident replicas, then retire the outgoing
        residency. Refuses up front when the swap would strand open
        sessions with nowhere to go (no other replica at this stage hosts
        ``from_name``) — the controller treats that as "hold"."""
        rep = self._replica_by_id(worker_id)
        if rep is None:
            raise KeyError(f"no replica {worker_id}")
        if from_name not in rep.resident:
            raise ResidencyError(
                f"{worker_id} does not host {from_name!r}")
        retiring = from_name != self.default_model
        incumbent = [sid for sid, sess in rep.sessions.items()
                     if (sess.model or self.default_model) == from_name]
        if retiring and incumbent and not self.decode_replicas(
                rep.stage, exclude=rep, model=from_name):
            raise ResidencyError(
                f"swap {from_name!r}->{to_name!r} on {worker_id} would "
                f"strand {len(incumbent)} open session(s): no other "
                f"replica at stage {rep.stage} hosts {from_name!r}")
        report = await self.bootstrap.load_model(
            rep, to_name, warm=warm, swap_from=from_name)
        evicted = self.registry.load(worker_id, to_name)
        for m in evicted:
            rep.resident.discard(m)
        rep.resident.add(to_name)
        if not retiring:
            # the default model can never retire (untagged traffic must
            # stay routable) — swapping "from" it just adds the target;
            # incumbent sessions stay put and keep serving
            self._retag_replica(rep)
        else:
            # advertise the incoming model at once, but stop NEW sessions
            # of the outgoing one from landing while incumbents migrate
            # off (pinned decode steps bypass rotation picks, so open
            # sessions keep flowing through the whole window)
            advertise = set(rep.resident) - {from_name}
            for world, router in rep.upstream_edges:
                router.set_models(world, advertise)
            for sid in incumbent:
                if sid in rep.sessions:
                    await self.migrations.migrate_session(rep, sid)
            # sweep stragglers — failed migrations and prefills that raced
            # the retag: bounce to re-prefill (at-least-once recovery,
            # client-invisible), so the registry sees zero refs below
            for sid, sess in list(rep.sessions.items()):
                if (sess.model or self.default_model) == from_name:
                    rep.drop_session(sid)
            self.registry.unload(worker_id, from_name)
            rep.resident.discard(from_name)
            self._retag_replica(rep)
        self.swaps_total += 1
        self._event("model_swap",
                    f"{worker_id}: {from_name} -> {to_name} "
                    f"[{report['source']}]")
        return report

    # ------------------------------------------------------------- scale-down
    async def remove_replica(self, stage: int,
                             worker_id: Optional[str] = None, *,
                             role: Optional[str] = None,
                             drain: bool = True,
                             timeout: float = 30.0,
                             migrate: bool = True) -> str:
        """Retire one replica of ``stage``.

        ``drain=True`` (scale-down): first hand every open session off live
        to a same-stage survivor (``migrate=True``, the state-transfer
        path: zero re-prefill, steps held during the handoff and released
        on the survivor), then stop routing to it — which also unpins any
        session that could *not* be migrated, so those relocate through the
        client's re-prefill fallback — then wait until its inbox, in-flight
        work, and adjacent transport channels are all empty, then tear its
        worlds down. Zero request/token loss by construction.
        ``migrate=False`` restores the PR 2 behavior (every open session
        pays a full re-prefill); bench_migrate measures the difference.
        ``drain=False`` (heal): the replica is already dead; just unhook the
        bookkeeping and purge its (broken) worlds so a replacement can be
        instantiated cleanly.

        ``role=`` restricts the victim choice to that pool (the per-role
        scale-down path). Whatever selected the victim, a drain refuses to
        remove the last replica *capable* of a role the victim serves —
        draining the last prefill-capable replica would strand every new
        session, and the last decode-capable one every open session, even
        if other pools still have capacity.
        """
        reps = self.replicas[stage]
        if worker_id is not None:
            rep = next((r for r in reps if r.worker_id == worker_id), None)
            if rep is None:
                raise KeyError(f"no replica {worker_id} in stage {stage}")
        else:
            live = [r for r in reps if r.worker.alive and not r.draining
                    and (role is None or r.role == role)]
            if not live:
                raise RuntimeError(
                    f"stage {stage} has no removable replica"
                    + (f" in role {role!r}" if role else ""))
            rep = min(live, key=lambda r: (r.open_sessions(),
                                           r.queue_depth()))
        if drain:
            others = [r for r in reps if r is not rep
                      and r.worker.alive and not r.draining]
            for cap in (ROLE_PREFILL, ROLE_DECODE):
                if rep.role in ROLE_CAPABLE[cap] and not any(
                        r.role in ROLE_CAPABLE[cap] for r in others):
                    raise RuntimeError(
                        f"refusing to drain the last healthy "
                        f"{cap}-capable replica of stage {stage}")

        rep.draining = True
        self._event("drain_begin", rep.worker_id)
        # 1. live handoff: move every open session's KV state to a survivor
        #    and flip its pins — the client never notices. Sessions that
        #    can't move (no survivor, transfer failure) fall through to the
        #    re-prefill path when their pins drop in step 2.
        #    Draft sessions never migrate: their caches are draft-model
        #    state no decode/prefill survivor could serve, and the client
        #    rebuilds them from the committed history in one PROPOSE —
        #    sessions degrade to plain decode, they do not relocate.
        if drain and migrate and rep.sessions and rep.role != ROLE_DRAFT:
            await self.migrations.migrate_replica_sessions(rep)
        # 2. stop routing new work to it (no new picks can reach these
        #    worlds once removed; an already-picked send has already been
        #    appended to the channel — the drain wait below flushes it).
        #    Removing also drops session pins: open sessions relocate via
        #    the client's re-prefill path instead of waiting forever.
        for world, router in rep.upstream_edges:
            router.remove(world)
        # 2. drain to zero
        if drain:
            await self._drain(rep, timeout)
        # 3. teardown in one event-loop tick
        self._teardown_replica(rep)
        self._event("remove_replica", rep.worker_id)
        return rep.worker_id

    async def _drain(self, rep: _Replica, timeout: float) -> None:
        transport = self.cluster.transport
        deadline = time.monotonic() + timeout

        def flushed() -> bool:
            # broken worlds are excluded: their pump (ours or the peer's)
            # is dead, so whatever sits in those channels can never flush —
            # waiting on them turned every heal-drain of a fenced replica
            # into a guaranteed full-timeout stall. Payloads wedged in a
            # broken world are already lost to the at-least-once resend
            # path, exactly as if the world had been torn down.
            return (rep.inbox.empty() and not rep._stash
                    and rep.inflight == 0
                    and all(transport.pending(w) == 0
                            for w in rep.upstream
                            if w not in self.broken_worlds)
                    and all(transport.pending(w) == 0
                            for w in rep.router.worlds
                            if w not in self.broken_worlds))

        while True:
            # A pump can be suspended on a fairness yield *between* popping a
            # payload off the channel and enqueueing it (neither place counts
            # it) — one scheduler pass lets any such pump land its payload,
            # so only two consecutive flushed observations prove empty.
            if flushed():
                await asyncio.sleep(0)
                if flushed():
                    return
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"drain of {rep.worker_id} exceeded {timeout}s "
                    f"(queue={rep.queue_depth()})")
            await asyncio.sleep(0.005)

    def _teardown_replica(self, rep: _Replica) -> None:
        """Unhook a replica and remove its worlds on every member in one
        synchronous pass — no await between key deletions, so no watchdog
        cycle can observe a half-removed world and fence it spuriously."""
        for task in (rep._run_task, rep._reap_task):
            if task is not None and not task.done():
                task.cancel()
        for sid in list(rep.sessions):
            rep.drop_session(sid)   # paged pages go back to the pool
        rep.held.clear()
        rep.migrated.clear()
        self.expired_retired += rep.expired
        for world in list(rep.upstream):
            rep.drop_upstream(world)
            self._world_to_replica.pop(world, None)
            self._remove_world_everywhere(world)
        for world in list(rep.router.worlds):
            down = self._world_to_replica.pop(world, None)
            if down is not None:
                down.drop_upstream(world)
            collector = self._collectors.pop(world, None)
            if collector is not None and not collector.done():
                collector.cancel()
            rep.router.remove(world)
            self._remove_world_everywhere(world)
        for world in rep.handoff_worlds:
            # persistent handoff channels die with either endpoint; the
            # partner's set keeps a stale name, which is harmless — peers
            # are only ever picked among live replicas
            self._remove_world_everywhere(world)
        rep.handoff_worlds.clear()
        if rep in self.replicas[rep.stage]:
            self.replicas[rep.stage].remove(rep)
        # reclaim the worker: stop its watchdog task and drop it from the
        # cluster registry, or every scale/heal cycle leaks one worker whose
        # heartbeat loop ticks forever
        worker = self.cluster.workers.pop(rep.worker_id, None)
        if worker is not None:
            worker.kill()
            worker.manager.shutdown()
        self.cluster.topology.forget(rep.worker_id)
        # its residencies and session refcounts die with it
        self.registry.drop_worker(rep.worker_id)
        # its worlds and channels are gone with it — drop the transport's
        # death record too, or the map grows one entry per heal forever
        self.cluster.transport.forget_dead(rep.worker_id)
        # the dedup guard is keyed by worker id; a retired id must not
        # block re-wiring if a future replica ever reuses the name
        self._wired_managers.discard(rep.worker_id)

    def _remove_world_everywhere(self, world: str) -> None:
        for worker in list(self.cluster.workers.values()):
            if world in worker.manager.worlds:
                worker.manager.remove_world(world)
        # a torn-down world can never break again — keeping it in the
        # fenced set would grow one entry per kill for the process lifetime
        # (and _drain/_edge_load only consult it for *live* worlds)
        self.broken_worlds.discard(world)

    # ---------------------------------------------------------------- serving
    def _watch_client_world(self, world: str) -> None:
        self._collectors[world] = self.client.spawn(self._collect(world))

    async def _collect(self, world: str) -> None:
        comm = self.client.comm
        try:
            while True:
                env = await comm.recv(0, world)
                fut = self._responses.pop(env.req_id, None)
                if fut is not None and not fut.done():
                    fut.set_result(env)
        except (WorldBrokenError, WorldNotFoundError, asyncio.CancelledError):
            return

    async def _roundtrip(self, env: Envelope, world: str,
                         timeout: float) -> Envelope:
        """Send one envelope to an entry world, await its response envelope.
        Marks the world broken/removed in the client rotation on send
        failure before re-raising."""
        fut: asyncio.Future = asyncio.get_event_loop().create_future()
        self._responses[env.req_id] = fut
        self._response_worlds[env.req_id] = world
        try:
            await self.client.comm.send(env, 1, world)
            return await asyncio.wait_for(fut, timeout)
        except WorldBrokenError:
            self.client_router.mark_broken(world)
            raise
        except WorldNotFoundError:
            self.client_router.remove(world)
            raise
        finally:
            self._responses.pop(env.req_id, None)
            self._response_worlds.pop(env.req_id, None)
            if fut.done() and not fut.cancelled():
                # the break callback may poison the future while the send
                # itself is raising — consume the exception so asyncio
                # doesn't log it as never-retrieved
                fut.exception()

    async def _restore_replay(self, sid: int, out: list, s0: int,
                              step_timeout: float, *,
                              count_failures: bool = True,
                              parent=None) -> bool:
        """Unplanned-loss recovery, cheap path: rebuild the session's route
        from live survivor state + background snapshots
        (``MigrationManager.restore_session``), then replay only the decode
        steps since the oldest restored cursor — the client still holds
        every generated token, and greedy decode is deterministic, so the
        replayed responses are discarded. Returns True when the session is
        live and caught up; False sends the caller to full re-prefill."""
        t_r = time.monotonic()
        t0 = await self.migrations.restore_session(
            sid, count_failures=count_failures, parent=parent)
        if t0 is None:
            return False
        replayed = 0
        rctx = None
        t_step = t_r
        try:
            # positions t0+1 .. s0+len(out)-2 were generated but lost from
            # every cache; feeding out[k] at position s0+k re-integrates it
            for k in range(t0 + 1 - s0, len(out) - 1):
                world = self.client_router.pinned(sid)
                if world is None:
                    return False
                t_step = time.monotonic()
                rctx = self.tracer.begin(parent)
                env = Envelope(
                    next(self._req_ids), sid, Kind.DECODE, step=s0 + k,
                    deadline=time.monotonic() + step_timeout,
                    payload=out[k][:, None], role=ROLE_DECODE,
                    trace=rctx, model=self.session_models.get(sid),
                    tenant=self.session_tenants.get(sid))
                resp = await self._roundtrip(env, world, step_timeout)
                # the replay ctx rode an envelope a stage may have spanned
                # under — record it even on a bad response so no stage span
                # is left parentless
                self.tracer.record(rctx, "decode_step", t_step,
                                   time.monotonic() - t_step, CLIENT,
                                   "replay")
                rctx = None
                if resp.kind is not Kind.DECODE:
                    return False
                replayed += 1
        except (WorldBrokenError, WorldNotFoundError, asyncio.TimeoutError):
            self.tracer.record(rctx, "decode_step", t_step,
                               time.monotonic() - t_step, CLIENT,
                               "replay_error")
            return False
        finally:
            self.migrations.recomputed_tokens += replayed
        self.tracer.span(parent, "restore_replay", t_r, CLIENT,
                         f"replayed={replayed}")
        return True

    def _live_heal_possible(self, sid: int) -> bool:
        """True while an alive-but-fenced replica still holds this session's
        state live — the controller's heal loop will live-migrate that state
        to a survivor, so a bounced client should wait a grace window and
        re-try the cheap restore path instead of re-prefilling immediately."""
        for stage in range(self.n_stages):
            failed = self.failed_replicas(stage)
            if not failed:
                continue
            for rep in self.replicas[stage]:
                if (rep.worker_id in failed and rep.worker.alive
                        and (sid in rep.sessions or sid in rep.held)):
                    return True
        return False

    async def _restore_with_grace(self, sid: int, out: list, s0: int,
                                  step_timeout: float,
                                  parent=None) -> bool:
        """Cheap-path recovery with a heal grace window: keep re-trying
        restore while a live heal can still deliver this session's state to
        a survivor (see :meth:`_live_heal_possible`); give up to the
        re-prefill fallback as soon as that hope is gone or the window
        closes. The probes suppress the failure counter — one bounce is
        one logical recovery event, counted once on final failure."""
        deadline = time.monotonic() + self.restore_grace_s
        while True:
            if await self._restore_replay(sid, out, s0, step_timeout,
                                          count_failures=False,
                                          parent=parent):
                return True
            if not (self._live_heal_possible(sid)
                    and time.monotonic() < deadline):
                self.migrations.restore_failures += 1
                return False
            await asyncio.sleep(0.02)

    async def _propose_draft(self, sid: int, hist: np.ndarray, k: int,
                             step_timeout: float,
                             tenant: Optional[str]) -> Optional[np.ndarray]:
        """One PROPOSE round against the session's pinned draft replica
        (picked from the draft pool and pinned on first use, so one
        replica accumulates the session's draft cache). ANY failure — no
        draft pool, a draining pool answering RETRY, a killed world, a
        timeout — returns None and unpins, degrading this round to plain
        decode with zero client-visible impact. Draft traffic rides the
        negated session id so the statexfer restore/snapshot machinery
        (keyed on the real sid) never confuses draft-model state with a
        target-model stage slice."""
        key = ("draft", sid)
        world = self.client_router.pinned(key)
        if world is None:
            world = self.client_router.try_pick(self.least_loaded,
                                                role=ROLE_DRAFT)
            if world is None:
                return None
            self.client_router.pin(key, world)
        env = Envelope(next(self._req_ids), -sid, Kind.PROPOSE,
                       step=hist.shape[1] - 1,
                       deadline=time.monotonic() + step_timeout,
                       payload=jnp.asarray(hist, jnp.int32), spec_k=k,
                       role=ROLE_DRAFT, tenant=tenant)
        try:
            resp = await self._roundtrip(env, world, step_timeout)
        except (WorldBrokenError, WorldNotFoundError, asyncio.TimeoutError):
            self.client_router.unpin(key)
            return None
        if resp.kind is not Kind.PROPOSE or resp.payload is None:
            self.client_router.unpin(key)
            return None
        props = np.asarray(resp.payload)
        if props.ndim != 2 or props.shape[1] < 1:
            self.client_router.unpin(key)
            return None
        return props[:, :k].astype(np.int32)

    async def _finish_draft(self, sid: int) -> None:
        """Release the session's draft-side state (pin + draft replica's
        cache); best-effort — the draft TTL reap is the backstop."""
        key = ("draft", sid)
        world = self.client_router.pinned(key)
        self.client_router.unpin(key)
        if world is not None:
            try:
                await self.client.comm.send(
                    Envelope(next(self._req_ids), -sid, Kind.FINISH, step=0),
                    1, world)
            except (WorldBrokenError, WorldNotFoundError):
                pass

    async def _abandon_session(self, sid: int) -> None:
        """The client is giving up on this session id for good (re-prefill
        under a fresh one follows). Surviving stages deliberately kept their
        slices alive for the restore path — sweep what the remaining pins
        can still reach with a best-effort FINISH so that state is released
        now rather than at the TTL reap."""
        world = self.client_router.pinned(sid)
        self.client_router.unpin(sid)
        if world is not None:
            try:
                await self.client.comm.send(
                    Envelope(next(self._req_ids), sid, Kind.FINISH, step=0),
                    1, world)
            except (WorldBrokenError, WorldNotFoundError):
                pass
        await self._finish_draft(sid)
        self.session_margins.pop(sid, None)
        self.session_models.pop(sid, None)
        self.session_tenants.pop(sid, None)

    async def _pick_entry(self, timeout: float,
                          role: Optional[str] = None,
                          model: Optional[str] = None) -> Optional[str]:
        deadline = time.monotonic() + timeout
        while True:
            world = self.client_router.try_pick(self.least_loaded, role=role,
                                                model=model)
            if world is not None:
                return world
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return None
            # the any-world event may already be set while the role's pool
            # is empty (controller still growing it) — bound each wait and
            # re-check the role-filtered rotation
            try:
                await asyncio.wait_for(self.client_router.wait_healthy(),
                                       min(0.05, remaining))
            except asyncio.TimeoutError:
                pass

    async def submit(self, tokens: np.ndarray, *, timeout: float = 30.0,
                     retries: int = 2,
                     model: Optional[str] = None) -> jax.Array:
        """Score a token batch through the pipeline; returns logits (B,S,V).

        Beyond-paper nicety: at-least-once redispatch — if a replica dies
        with the request in flight, the client re-sends after ``timeout``.
        A fully-empty stage-0 rotation (every entry replica down) parks the
        attempt until the controller heals one, instead of failing fast.
        ``model=`` scores under a non-default registered model (the route
        restricts to replicas hosting it).
        """
        x = jnp.asarray(tokens, jnp.int32)
        if model is not None:
            self.registry.get(model)   # fail fast, with a suggestion
        last_err: Optional[Exception] = None
        for _ in range(retries + 1):
            world = await self._pick_entry(timeout, role=ROLE_PREFILL,
                                           model=model)
            if world is None:
                last_err = asyncio.TimeoutError("no healthy entry replica")
                continue
            env = Envelope(next(self._req_ids), -1, Kind.SCORE, payload=x,
                           role=ROLE_PREFILL, model=model)
            try:
                resp = await self._roundtrip(env, world, timeout)
                return resp.payload
            except (WorldBrokenError, WorldNotFoundError,
                    asyncio.TimeoutError) as e:
                last_err = e
        raise RuntimeError(f"request failed after {retries + 1} attempts: "
                           f"{last_err}")

    async def generate(self, prompts: np.ndarray, max_new_tokens: int, *,
                       step_timeout: float = 10.0, max_restarts: int = 32,
                       token_times: Optional[list] = None,
                       model: Optional[str] = None,
                       tenant: Optional[str] = None,
                       spec_k: Optional[int] = None) -> np.ndarray:
        """Greedy autoregressive generation through the pipeline.

        prompts (B, S) int32 -> (B, max_new_tokens) int32, token-identical
        to single-engine ``ServeEngine.generate`` at temperature 0.

        Fault story: the session's per-stage KV caches live on the replicas
        that prefilled it. If any of them dies or drains mid-generation, the
        pipeline answers RETRY (or the client's pin check fails, or the step
        times out) and the client re-prefills prompt + everything generated
        so far on surviving replicas — at-least-once recovery with zero
        token loss, since generated tokens only ever live client-side.

        ``model=`` generates under a non-default registered model: routing,
        executors, and recovery all follow the tag, so parity holds against
        that model's own single engine. ``tenant=`` attributes the session
        to a tenant for fair scheduling and per-tenant latency sketches.

        ``spec_k=`` overrides the pipeline's speculative-decoding budget
        for this session (None = pipeline default; 0 = plain decode). With
        a draft pool present, each decode round PROPOSEs k draft tokens
        and VERIFYs them in one batched target dispatch — greedy argmax of
        the target logits at every position, so the output stays token-
        identical to plain decode. Any draft failure silently degrades the
        round to plain decode.
        """
        k_cfg = self.spec_k if spec_k is None else int(spec_k)
        seq = jnp.asarray(prompts, jnp.int32)
        bsz, s0 = seq.shape
        assert s0 + max_new_tokens <= self.max_len, \
            f"{s0}+{max_new_tokens} exceeds pipeline max_len {self.max_len}"
        if model is not None:
            # unknown tags fail fast with a closest-match suggestion
            # instead of parking on a rotation no replica will ever join
            self.registry.get(model)
        if tenant is not None:
            self.tenant_sessions[tenant] = (
                self.tenant_sessions.get(tenant, 0) + 1)
        out: list[np.ndarray] = []
        sid: Optional[int] = None
        hist_len = s0
        base = 0        # tokens already inside the current prefill history
        restarts = 0
        tracer = self.tracer
        # the *client* owns the session's root span: a re-prefill changes
        # the session id but not the trace, so RETRY bounces, restores, and
        # the resumed decode all reconstruct under one tree
        root = tracer.begin()
        t_root = time.monotonic()
        #: last client span ctx sent but not yet recorded — the failure
        #: handler closes it, so a stage-side child span never outlives an
        #: unrecorded parent (timeouts would otherwise orphan the subtree)
        pending = None
        while len(out) < max_new_tokens:
            try:
                if sid is None:
                    # (re-)prefill the full history on any healthy entry
                    hist = (seq if not out else
                            jnp.concatenate([seq, jnp.stack(out, 1)], 1))
                    hist_len = hist.shape[1]
                    base = len(out)
                    world = await self._pick_entry(step_timeout,
                                                   role=ROLE_PREFILL,
                                                   model=model)
                    if world is None:
                        raise _SessionLost("no healthy entry replica")
                    sid = next(self._session_ids)
                    if model is not None:
                        self.session_models[sid] = model
                    if tenant is not None:
                        self.session_tenants[sid] = tenant
                    t_send = time.monotonic()
                    ctx = tracer.begin(root)
                    pending = ("ttft", ctx, t_send)
                    env = Envelope(
                        next(self._req_ids), sid, Kind.PREFILL,
                        step=hist_len - 1,
                        deadline=time.monotonic() + step_timeout,
                        payload=hist, role=ROLE_PREFILL, trace=ctx,
                        model=model, tenant=tenant)
                    resp = await self._roundtrip(env, world, step_timeout)
                    if resp.kind is Kind.RETRY:
                        tracer.record(ctx, "ttft", t_send,
                                      time.monotonic() - t_send, CLIENT,
                                      "retry")
                        pending = None
                        raise _SessionLost("prefill bounced")
                    if resp.kind is Kind.FINISH:
                        raise _SessionLost(resp.error or "server finished")
                    dt = time.monotonic() - t_send
                    self._note_latency(self.ttft_log, dt)
                    self._note_tenant(tenant, "ttft", dt)
                    tracer.record(ctx, "ttft", t_send, dt, CLIENT)
                    pending = None
                    if self.client_router.pinned(sid) is None:
                        # a split stage-0 already stitched the pin onto the
                        # session's decode home during the prefill pass —
                        # only the colocated path pins the entry world here
                        self.client_router.pin(sid, world)
                else:
                    world = self.client_router.pinned(sid)
                    if world is None:
                        raise _SessionLost("entry replica gone")
                    # speculative round: k bounded so even full acceptance
                    # (k proposals + the bonus token) cannot overshoot the
                    # requested generation length
                    k_round = min(k_cfg, max_new_tokens - len(out) - 1)
                    props = None
                    if k_round >= 1:
                        hist_now = np.concatenate(
                            [np.asarray(seq)] +
                            [np.asarray(t)[:, None] for t in out], axis=1)
                        props = await self._propose_draft(
                            sid, hist_now, k_round, step_timeout, tenant)
                        if props is None:
                            # degrade: this round rides the plain DECODE
                            # path below; the next round re-picks a draft
                            self.spec_fallbacks_total += 1
                    if props is not None:
                        t_send = time.monotonic()
                        ctx = tracer.begin(root)
                        pending = ("verify_step", ctx, t_send)
                        payload = np.concatenate(
                            [np.asarray(out[-1])[:, None], props],
                            axis=1).astype(np.int32)
                        env = Envelope(
                            next(self._req_ids), sid, Kind.VERIFY,
                            step=hist_len + (len(out) - base) - 1,
                            deadline=time.monotonic() + step_timeout,
                            payload=jnp.asarray(payload), spec_k=k_round,
                            role=ROLE_DECODE, trace=ctx, model=model,
                            tenant=tenant)
                        resp = await self._roundtrip(env, world,
                                                     step_timeout)
                        if resp.kind is Kind.RETRY:
                            tracer.record(ctx, "verify_step", t_send,
                                          time.monotonic() - t_send,
                                          CLIENT, "retry")
                            pending = None
                            raise _SessionLost("verify bounced")
                        if resp.kind is Kind.FINISH:
                            raise _SessionLost(
                                resp.error or "server finished")
                        dt = time.monotonic() - t_send
                        self._note_latency(self.decode_lat_log, dt)
                        self._note_tenant(tenant, "decode", dt)
                        tracer.record(ctx, "verify_step", t_send, dt,
                                      CLIENT)
                        pending = None
                        # (B, m+1) accepted prefix + bonus token — every
                        # column is the target model's own greedy argmax,
                        # so appending the whole block preserves parity
                        committed = np.asarray(resp.payload)
                        self.spec_rounds_total += 1
                        self.spec_proposed_total += k_round
                        self.spec_accepted_total += committed.shape[1] - 1
                        t_now = time.monotonic()
                        for j in range(committed.shape[1]):
                            out.append(committed[:, j].astype(np.int32))
                            if tenant is not None:
                                self.tenant_tokens[tenant] = (
                                    self.tenant_tokens.get(tenant, 0)
                                    + bsz)
                            if token_times is not None:
                                token_times.append(t_now)
                        continue
                    # position of the fed token: history end + tokens
                    # generated since that history was prefilled
                    t_send = time.monotonic()
                    ctx = tracer.begin(root)
                    pending = ("decode_step", ctx, t_send)
                    env = Envelope(
                        next(self._req_ids), sid, Kind.DECODE,
                        step=hist_len + (len(out) - base) - 1,
                        deadline=time.monotonic() + step_timeout,
                        payload=out[-1][:, None], role=ROLE_DECODE,
                        trace=ctx, model=model, tenant=tenant)
                    resp = await self._roundtrip(env, world, step_timeout)
                    if resp.kind is Kind.RETRY:
                        tracer.record(ctx, "decode_step", t_send,
                                      time.monotonic() - t_send, CLIENT,
                                      "retry")
                        pending = None
                        raise _SessionLost("decode bounced")
                    if resp.kind is Kind.FINISH:
                        raise _SessionLost(resp.error or "server finished")
                    dt = time.monotonic() - t_send
                    self._note_latency(self.decode_lat_log, dt)
                    self._note_tenant(tenant, "decode", dt)
                    tracer.record(ctx, "decode_step", t_send, dt, CLIENT)
                    pending = None
                # greedy pick on the host: the logits are tiny (B,V) and a
                # jax dispatch per token per session would dominate the
                # client loop at smoke scale
                logits = np.asarray(resp.payload)
                self._note_margin(sid, logits)
                tok = np.argmax(logits, axis=-1).astype(np.int32)
                out.append(tok)
                if tenant is not None:
                    self.tenant_tokens[tenant] = (
                        self.tenant_tokens.get(tenant, 0) + bsz)
                if token_times is not None:
                    token_times.append(time.monotonic())
            except (_SessionLost, asyncio.TimeoutError,
                    WorldBrokenError, WorldNotFoundError) as e:
                if pending is not None:
                    # the step died without a response; close its span so
                    # any stage-side child recorded before the failure
                    # still parents back into the tree
                    p_kind, p_ctx, p_t = pending
                    tracer.record(p_ctx, p_kind, p_t,
                                  time.monotonic() - p_t, CLIENT,
                                  f"error={type(e).__name__}")
                    pending = None
                restarts += 1
                if restarts > max_restarts:
                    raise RuntimeError(
                        f"generation failed after {max_restarts} session "
                        f"restarts: {e}") from e
                if sid is not None:
                    if out and await self._restore_with_grace(
                            sid, out, s0, step_timeout, parent=root):
                        # session restored + caught up: resume decoding with
                        # the step arithmetic re-anchored to the raw prompt
                        hist_len, base = s0, 0
                        continue
                    await self._abandon_session(sid)
                    if out:
                        self.migrations.reprefills_total += 1
                        self.migrations.recomputed_tokens += s0 + len(out)
                        # zero-length marker span: the recovery fell through
                        # to the full re-prefill path (the PREFILL that
                        # follows carries its own ttft span under root)
                        tracer.span(root, "reprefill", time.monotonic(),
                                    CLIENT, str(e))
                sid = None           # forces re-prefill with full history
        if sid is not None:
            world = self.client_router.pinned(sid)
            self.client_router.unpin(sid)
            if world is not None:
                env = Envelope(next(self._req_ids), sid, Kind.FINISH,
                               step=hist_len + (len(out) - base) - 1)
                try:
                    await self.client.comm.send(env, 1, world)
                except (WorldBrokenError, WorldNotFoundError):
                    pass
            await self._finish_draft(sid)
            if self.snapshots is not None:
                # eager snapshot GC; the background sweep + TTL are backstops
                self.snapshots.drop_session(sid)
            self.session_margins.pop(sid, None)
            self.session_models.pop(sid, None)
            self.session_tenants.pop(sid, None)
        tracer.record(root, "session", t_root, time.monotonic() - t_root,
                      CLIENT, f"tokens={len(out)} restarts={restarts}")
        return np.stack([np.asarray(t) for t in out], axis=1)

    # ------------------------------------------------------------------ intro
    def healthy_replicas(self, stage: int,
                         role: Optional[str] = None) -> list[str]:
        out = []
        for rep in self.replicas[stage]:
            if not rep.worker.alive or rep.draining:
                continue
            if role is not None and rep.role != role:
                continue
            out.append(rep.worker_id)
        return out

    def failed_replicas(self, stage: int) -> list[str]:
        """Heal candidates: replicas the watchdog has cut off — every
        upstream edge fenced, so no traffic can reach them (or the worker
        is outright dead)."""
        out = []
        for rep in self.replicas[stage]:
            if rep.draining:
                continue
            dead = not rep.worker.alive
            cut_off = bool(rep.upstream) and all(
                w in self.broken_worlds for w in rep.upstream)
            if dead or cut_off:
                out.append(rep.worker_id)
        return out

    def open_sessions(self, stage: int) -> int:
        return sum(r.open_sessions() for r in self.replicas[stage]
                   if r.worker.alive)

    def replica_stats(self) -> dict[str, dict[str, Any]]:
        """Introspection snapshot of the raw per-replica load counters
        (MetricsHub reads the ``_Replica`` attributes directly; this is the
        public debugging/dashboard view of the same signals)."""
        out: dict[str, dict[str, Any]] = {}
        for stage, reps in enumerate(self.replicas):
            for rep in reps:
                out[rep.worker_id] = {
                    "stage": stage,
                    "role": rep.role,
                    "alive": rep.worker.alive,
                    "draining": rep.draining,
                    "queue_depth": rep.queue_depth(),
                    "inflight": rep.inflight,
                    "processed": rep.processed,
                    "wait_s_sum": rep.wait_s_sum,
                    "service_s_sum": rep.service_s_sum,
                    "parked": rep.parked,
                    "tokens_out": rep.tokens_out,
                    "open_sessions": rep.open_sessions(),
                    "decode_batches": rep.decode_batches,
                    "decode_steps": rep.decode_steps,
                    "retries_sent": rep.retries_sent,
                    "expired": rep.expired,
                    "held_sessions": len(rep.held),
                    "migrated_away": len(rep.migrated),
                    "prefills": rep.prefills,
                    "handoffs_out": rep.handoffs_out,
                    "models": sorted(rep.resident),
                    "tenant_served": dict(rep.tenant_served),
                    "spec_verifies": rep.spec_verifies,
                    "spec_proposed": rep.spec_proposed,
                    "spec_accepted": rep.spec_accepted,
                    "spec_proposals": rep.spec_proposals,
                }
        return out

"""StageExecutor: shared compile-reuse prefill/decode execution.

One instance serves one pipeline stage (all replicas of the stage share it,
and therefore share its jit cache) or the whole model as a single stage
(``ServeEngine``). It owns the three compute paths of the generative data
plane:

* :meth:`score`   — stateless teacher-forced forward (legacy submit path)
* :meth:`prefill` — build a per-session decode cache from a token history
* :meth:`decode` / :meth:`decode_many` — one autoregressive step for a
  single session, or one fused dispatch over N stacked sessions at
  *heterogeneous* positions (the continuous-batching hot path)

Compile reuse: jit already caches one executable per input shape; the
executor additionally right-pads prefill sequence lengths up to power-of-two
buckets so arbitrary history lengths (which re-prefill after a failure makes
common) hit a small set of executables instead of compiling per length.
Padding is only applied when every group in the stage slice uses a full
(non-ring, non-SSM) cache: causal masking makes right-padding invisible to
real positions there, while ring buffers would evict real keys and SSM
states would integrate the garbage tail.

``decode_many`` batches sessions by stacking their caches along a fresh
leading axis and ``vmap``-ing the single-step stage decode over it — each
session keeps its own position ``t``, so sessions that started at different
times still coalesce into one dispatch (same-``t``-only batching would never
converge once sessions drift).
"""
from __future__ import annotations

import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import DENSE, MOE, ModelConfig
from repro.statexfer.codec import PagedCachePayload, materialize_paged
from . import kvpool
from .envelope import ROLE_BOTH, ROLE_DECODE, ROLE_PREFILL
from .kvpool import PagedCacheHandle, PagePool
from .partition import (
    StageSpec,
    stage_decode,
    stage_forward,
    stage_init_cache,
    stage_params,
    stage_prefill,
    split_stages,
)


class StageExecutor:
    def __init__(self, cfg: ModelConfig, spec: StageSpec, sparams: Any, *,
                 max_len: int = 256, pad_seq: bool = True,
                 role: str = ROLE_BOTH, paged: bool = False,
                 page_size: int = 16,
                 pool_pages: int | None = None) -> None:
        self.cfg = cfg
        self.spec = spec
        self.sparams = sparams
        self.max_len = max_len
        #: which pool this executor serves: a ``prefill`` executor never
        #: compiles decode buckets, a ``decode`` executor never compiles the
        #: full prefill shape set — warm bootstrap replays only the role's
        #: slice of a peer's shape profile (see :meth:`warm`)
        self.role = role
        groups = [cfg.groups[gi] for gi, _, _ in spec.slices]
        #: every group uses a full (non-ring, non-SSM) attention cache —
        #: gates right-padding here and replay-idempotent snapshot restore
        #: in statexfer (rewriting position t with the same inputs is an
        #: exact no-op only for full caches)
        self.full_cache = all(
            g.kind in (DENSE, MOE) and g.window is None for g in groups)
        #: right-padding is a pure win only for full-cache attention stages
        self.pad_seq = pad_seq and self.full_cache
        #: paged KV mode: prefill installs the session cache into a shared
        #: PagePool and returns a page-table handle; decode_many stacks page
        #: tables instead of whole caches. Gated on full caches (page writes
        #: rely on decode touching exactly slot t) and page-aligned max_len.
        #: The contiguous path stays as the fallback/degrade target.
        self.paged = bool(paged) and self.full_cache \
            and max_len % page_size == 0
        self.page_size = page_size
        self.pool_pages = pool_pages or (4 * (max_len // page_size) + 1)
        self.pool: PagePool | None = None
        self._pool_init_lock = threading.Lock()
        #: flight-event sink (set by the server: FlightRecorder.record)
        self.on_event = None
        self._paged_many = None
        self._paged_widths_seen: set[int] = set()
        #: cached all-zeros donor caches for convoy pad slots, one per
        #: distinct cache leaf signature (built once, reused every pad)
        self._pad_caches: dict = {}
        tokens_in = spec.first

        self._score = jax.jit(
            lambda sp, x: stage_forward(cfg, spec, sp, x, tokens_in=tokens_in))
        self._prefill = jax.jit(
            lambda sp, x: stage_prefill(cfg, spec, sp, x, max_len,
                                        tokens_in=tokens_in))
        self._decode = jax.jit(
            lambda sp, c, x, t: stage_decode(cfg, spec, sp, c, x, t,
                                             tokens_in=tokens_in))
        # N sessions, each with its own cache and position, in one dispatch:
        # vmap over a stacked leading axis keeps every per-session batch dim
        # intact, so the inner stage_decode is byte-for-byte the single path.
        # Stacking N caches and splitting the N results back apart happens
        # INSIDE the jitted function — done on the host it costs dozens of
        # tiny dispatches per fused batch and erases the batching win.
        def _many(sp, caches, xs, ts):
            stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *caches)
            x = jnp.stack(xs)
            outs, new_stacked = jax.vmap(
                lambda c, xi, ti: stage_decode(cfg, spec, sp, c, xi, ti,
                                               tokens_in=tokens_in),
                in_axes=(0, 0, 0))(stacked, x, ts)
            n = len(caches)
            return (tuple(outs[i] for i in range(n)),
                    tuple(jax.tree.map(lambda l: l[i], new_stacked)
                          for i in range(n)))

        self._decode_many = jax.jit(_many)

        self.stats = {"score_calls": 0, "prefill_calls": 0,
                      "decode_batches": 0, "decode_steps": 0,
                      "first_call_compile_s": 0.0, "warmed_dispatches": 0,
                      "paged_decode_batches": 0, "paged_degrades": 0}
        #: fused convoy widths already compiled (first-dispatch timing)
        self._widths_seen: set[int] = set()
        #: post-bucketing prefill input shapes served so far — together with
        #: the widths this is the executor's *warm profile*: exactly the
        #: executables a same-role executor needs compiled (WarmBootstrap)
        self._prefill_shapes_seen: set[tuple] = set()

    @classmethod
    def for_model(cls, model, params, *, max_len: int = 256,
                  pad_seq: bool = True, **kw) -> "StageExecutor":
        """Whole model as a single stage (the standalone-engine case)."""
        spec = split_stages(model.cfg, 1)[0]
        return cls(model.cfg, spec, stage_params(model.cfg, params, spec),
                   max_len=max_len, pad_seq=pad_seq, **kw)

    # ------------------------------------------------------------------ shapes
    @staticmethod
    def _bucket(n: int) -> int:
        b = 8
        while b < n:
            b *= 2
        return b

    @staticmethod
    def _width_bucket(n: int) -> int:
        b = 2
        while b < n:
            b *= 2
        return b

    def _timed(self, key: str, fn, *args):
        """Record first-dispatch wall time (dominated by jit compile — the
        analogue of the paper's NCCL lazy-init dip) per executor."""
        first = self.stats[key] == 0
        t0 = time.monotonic()
        out = fn(self.sparams, *args)
        if first:
            jax.block_until_ready(out)
            self.stats["first_call_compile_s"] += time.monotonic() - t0
        self.stats[key] += 1
        return out

    # ----------------------------------------------------------------- compute
    def score(self, x: jax.Array) -> jax.Array:
        """Teacher-forced forward: tokens/hidden (B,S[,D]) -> full output."""
        return self._timed("score_calls", self._score, x)

    def prefill(self, x: jax.Array) -> tuple[jax.Array, Any]:
        """History (B,S[,D]) -> (output sliced back to S, session cache).

        In paged mode the contiguous prefill result is installed into the
        shared PagePool (leading full pages deduped against the prefix
        trie) and a :class:`~repro.serving.kvpool.PagedCacheHandle` is
        returned instead; on template mismatch or pool exhaustion the
        session simply keeps the contiguous cache."""
        x0, s = x, x.shape[1]
        if self.pad_seq:
            sp = min(self._bucket(s), self.max_len)
            if sp > s:
                pad = [(0, 0), (0, sp - s)] + [(0, 0)] * (x.ndim - 2)
                x = jnp.pad(x, pad)
        self._prefill_shapes_seen.add((tuple(x.shape), str(x.dtype)))
        out, cache = self._timed("prefill_calls", self._prefill, x)
        if out.shape[1] != s:
            out = out[:, :s]
        if self.paged:
            keys = kvpool.prefix_chunk_keys(x0, s, self.page_size)
            handle = self._ensure_pool().install_prefill(cache, s, keys)
            if handle is not None:
                return out, handle
        return out, cache

    def decode(self, cache: Any, x: jax.Array, t) -> tuple[jax.Array, Any]:
        """Single-session step: token/hidden (B,1[,D]) at position ``t``."""
        if isinstance(cache, PagedCacheHandle):
            return self._paged_decode_many([cache], [x], [t])[0]
        out, new_cache = self._timed(
            "decode_steps", self._decode, cache, x, jnp.int32(t))
        self.stats["decode_batches"] += 1
        return out, new_cache

    def decode_many(self, caches: list[Any], xs: list[jax.Array],
                    ts: list[int]) -> list[tuple[jax.Array, Any]]:
        """One fused dispatch over N sessions (own cache + position each).

        All ``xs`` must share one shape (same per-session batch); positions
        are free. Returns per-session (output, new_cache) in input order.
        Paged and contiguous sessions may mix in one convoy: each kind
        dispatches fused with its peers and the results merge in order.

        Convoy widths are bucketed to powers of two by duplicating lane 0's
        input shape (results discarded): otherwise every distinct width
        2..max compiles its own executable mid-serving, a compile stall per
        new width — the decode-path analogue of the prefill sequence
        buckets. Pad slots carry a cached all-zeros donor cache (built once
        per leaf signature), not a stacked copy of a real session's cache.
        """
        paged_idx = [i for i, c in enumerate(caches)
                     if isinstance(c, PagedCacheHandle)]
        if paged_idx:
            results: list = [None] * len(caches)
            contig_idx = [i for i in range(len(caches))
                          if not isinstance(caches[i], PagedCacheHandle)]
            paged_out = self._paged_decode_many(
                [caches[i] for i in paged_idx],
                [xs[i] for i in paged_idx], [ts[i] for i in paged_idx])
            for i, r in zip(paged_idx, paged_out):
                results[i] = r
            if contig_idx:
                contig_out = self.decode_many(
                    [caches[i] for i in contig_idx],
                    [xs[i] for i in contig_idx], [ts[i] for i in contig_idx])
                for i, r in zip(contig_idx, contig_out):
                    results[i] = r
            return results
        n = len(caches)
        if n == 1:
            return [self.decode(caches[0], xs[0], ts[0])]
        width = self._width_bucket(n)
        if width > n:
            pad = width - n
            caches = list(caches) + [self._pad_cache(caches[0])] * pad
            xs = list(xs) + [xs[0]] * pad
            ts = list(ts) + [0] * pad
        t = jnp.asarray(ts, jnp.int32)
        first = width not in self._widths_seen
        self._widths_seen.add(width)
        t0 = time.monotonic()
        outs, new_caches = self._decode_many(
            self.sparams, tuple(caches), tuple(xs), t)
        if first:
            jax.block_until_ready(outs)
            self.stats["first_call_compile_s"] += time.monotonic() - t0
        self.stats["decode_batches"] += 1
        self.stats["decode_steps"] += n
        return list(zip(outs[:n], new_caches[:n]))

    def _pad_cache(self, like: Any) -> Any:
        """All-zeros donor cache for convoy pad slots, cached per leaf
        signature: padding with ``caches[0]`` stacked a real session's
        cache bytes once per pad lane per microbatch for results nobody
        reads."""
        key = tuple((tuple(leaf.shape), str(leaf.dtype))
                    for leaf in jax.tree.leaves(like))
        donor = self._pad_caches.get(key)
        if donor is None:
            donor = jax.tree.map(jnp.zeros_like, like)
            self._pad_caches[key] = donor
        return donor

    # ------------------------------------------------------------ paged mode
    def _ensure_pool(self) -> PagePool:
        with self._pool_init_lock:
            if self.pool is None:
                self.pool = PagePool(
                    self.cfg, self.spec, max_len=self.max_len,
                    page_size=self.page_size, num_pages=self.pool_pages,
                    on_event=self._pool_event)
        return self.pool

    def _pool_event(self, kind: str, **fields) -> None:
        if self.on_event is not None:
            self.on_event(kind, **fields)

    def adopt_cache(self, cache: Any) -> Any:
        """Normalize an installed session cache for this executor. Paged
        wire payloads enter the pool directly (page-granular restore, full
        prefix pages re-shared via the trie); without a usable pool they
        materialize to a contiguous cache. Handles and contiguous caches
        pass through."""
        if isinstance(cache, PagedCachePayload):
            if self.paged:
                handle = self._ensure_pool().install_payload(cache)
                if handle is not None:
                    return handle
            return materialize_paged(cache)
        return cache

    def release_cache(self, cache: Any) -> None:
        """Return a dropped session's pool pages (no-op for contiguous)."""
        if isinstance(cache, PagedCacheHandle):
            cache.pool.release(cache)

    def _paged_decode_many(self, handles: list, xs: list,
                           ts: list) -> list[tuple[jax.Array, Any]]:
        """Fused decode over paged sessions: host-side page-table upkeep
        (growth + copy-on-write), then one jitted dispatch that gathers
        each lane's cache through its page table and scatters back only the
        page containing its written slot. A session whose upkeep fails
        (pool exhausted) degrades to a contiguous cache and rides the
        contiguous path — never crashes."""
        n = len(handles)
        results: list = [None] * n
        caches = list(handles)
        live = []
        degraded = []
        # hold the pool lock across upkeep + dispatch + leaves writeback:
        # replicas share this executor and decode on worker threads, and a
        # concurrent dispatch reading the same pool arrays would lose this
        # one's page writes when it stores its own new arrays back
        with self._ensure_pool().lock:
            for i, (h, t) in enumerate(zip(handles, ts)):
                ok = (self.pool is not None and h.pool is self.pool
                      and self.pool.prepare_write(h, int(t)))
                if ok:
                    live.append(i)
                else:
                    caches[i] = h.pool.materialize(h)
                    h.pool.release(h)
                    self.stats["paged_degrades"] += 1
                    degraded.append(i)
            if live:
                outs = self._dispatch_paged([caches[i] for i in live],
                                            [xs[i] for i in live],
                                            [ts[i] for i in live])
                for i, r in zip(live, outs):
                    results[i] = r
        if degraded:
            fallback = self.decode_many([caches[i] for i in degraded],
                                        [xs[i] for i in degraded],
                                        [ts[i] for i in degraded])
            for i, r in zip(degraded, fallback):
                results[i] = r
        return results

    def _dispatch_paged(self, handles: list, xs: list,
                        ts: list) -> list[tuple[jax.Array, Any]]:
        pool = self.pool
        n = len(handles)
        width = n if n == 1 else self._width_bucket(n)
        tables = np.zeros((width, pool.pages_per_seq), np.int32)
        for i, h in enumerate(handles):
            tables[i, :len(h.pages)] = h.pages
        # pad lanes: all-zero tables target the reserved scratch page — the
        # gather reads garbage nobody looks at, the writeback lands on page 0
        xs_p = list(xs) + [xs[0]] * (width - n)
        ts_p = list(ts) + [0] * (width - n)
        fn = self._get_paged_many()
        first = width not in self._paged_widths_seen
        self._paged_widths_seen.add(width)
        t0 = time.monotonic()
        outs, new_leaves = fn(self.sparams, tuple(pool.leaves),
                              jnp.asarray(tables),
                              tuple(xs_p), jnp.asarray(ts_p, jnp.int32))
        if first:
            jax.block_until_ready(outs)
            self.stats["first_call_compile_s"] += time.monotonic() - t0
        pool.leaves = list(new_leaves)
        for h, t in zip(handles, ts):
            h.length = max(h.length, int(t) + 1)
        self.stats["decode_batches"] += 1
        self.stats["decode_steps"] += n
        self.stats["paged_decode_batches"] += 1
        return [(outs[i], handles[i]) for i in range(n)]

    def _get_paged_many(self):
        if self._paged_many is None:
            cfg, spec, pool = self.cfg, self.spec, self.pool
            tokens_in = spec.first
            axes = tuple(pool.axes)
            page = pool.page_size
            structure = jax.tree.structure(pool.skeleton)

            def _many_paged(sp, pool_leaves, tables, xs, ts):
                def one(table, x, t):
                    leaves = kvpool.gather_pages(pool_leaves, axes, table,
                                                 page)
                    cache = jax.tree.unflatten(structure, leaves)
                    out, new_cache = stage_decode(cfg, spec, sp, cache, x, t,
                                                  tokens_in=tokens_in)
                    new_leaves = structure.flatten_up_to(new_cache)
                    li = t // page
                    pg = [jax.lax.dynamic_slice_in_dim(
                        leaf, li * page, page, axis=ax)
                        for leaf, ax in zip(new_leaves, axes)]
                    return out, pg, table[li]

                x = jnp.stack(xs)
                outs, pgs, phys = jax.vmap(one, in_axes=(0, 0, 0))(
                    tables, x, ts)
                # distinct lanes own distinct physical pages (prepare_write
                # guarantees exclusivity); pad lanes all hit scratch page 0
                new_pool = tuple(
                    leaf.at[phys].set(pg)
                    for leaf, pg in zip(pool_leaves, pgs))
                return outs, new_pool

            self._paged_many = jax.jit(_many_paged)
        return self._paged_many

    # ---------------------------------------------------------- warm profile
    def warm_profile(self) -> dict:
        """What a same-role executor must compile to serve like this one:
        the bucketed prefill shapes served so far and the fused decode
        convoy widths dispatched so far (WarmBootstrap ships this from a
        peer replica to a fresh one)."""
        return {"prefill": sorted(self._prefill_shapes_seen),
                "widths": sorted(self._widths_seen)}

    def obs_stats(self) -> dict:
        """Flat numeric view of the executor for the metrics export
        surface: dispatch counters plus how much of the jit cache the
        served traffic has populated (warm-profile cardinality)."""
        out = dict(self.stats)
        out["prefill_shapes_compiled"] = len(self._prefill_shapes_seen)
        out["decode_widths_compiled"] = len(self._widths_seen)
        out["paged_widths_compiled"] = len(self._paged_widths_seen)
        if self.pool is not None:
            out.update(self.pool.stats())
        return out

    def pool_stats(self) -> dict:
        """Page-pool gauges for the kvpool metrics group ({} when the pool
        has not been built — no paged session served yet)."""
        return self.pool.stats() if self.pool is not None else {}

    def warm(self, profile: dict) -> int:
        """Replay a peer's warm profile with dummy inputs so every listed
        executable is compiled before real traffic arrives. Returns the
        number of warm dispatches issued. Dummy results are discarded; the
        dispatches land in the shared jit cache, which is the entire point.

        Role filtering (disaggregated pools): a ``prefill`` executor replays
        only the prefill shape set — its replicas never decode, so compiling
        decode convoy widths would burn warm time on executables the jit
        cache never serves. A ``decode`` executor skips prefill compiles
        entirely: its caches arrive pre-built over the handoff wire, so the
        donor caches for width warmup are constructed host-side with
        :func:`stage_init_cache` (an allocation, not a compile) — one per
        distinct batch shape instead of one prefill executable per sequence
        bucket. Either way the role's warm bootstrap is strictly cheaper
        than the colocated profile replay.
        """
        if self.role == ROLE_DECODE:
            return self._warm_decode_only(profile)
        dispatches = 0
        widths = (list(profile.get("widths", []))
                  if self.role != ROLE_PREFILL else [])
        for shape, dtype in profile.get("prefill", []):
            x = jnp.zeros(shape, dtype=jnp.dtype(dtype))
            # go through the jitted callable directly: prefill() would
            # re-bucket (already-bucketed shapes pass through unchanged) and
            # pollute the first-call timing stats
            out, cache = self._prefill(self.sparams, x)
            jax.block_until_ready(out)
            self._prefill_shapes_seen.add((tuple(shape), str(dtype)))
            dispatches += 1
            if self.role == ROLE_PREFILL:
                continue
            # decode warmup needs a live cache of the right batch; reuse the
            # one this prefill just built
            step_x = jnp.zeros((shape[0], 1) + tuple(shape[2:]),
                               dtype=jnp.dtype(dtype))
            t = min(shape[1], self.max_len - 1)
            for w in widths:
                outs = self.decode_many([cache] * w, [step_x] * w, [t] * w)
                jax.block_until_ready(outs[0][0])
                dispatches += 1
            if not widths:
                out2, _ = self.decode(cache, step_x, t)
                jax.block_until_ready(out2)
                dispatches += 1
        self.stats["warmed_dispatches"] += dispatches
        return dispatches

    def _warm_decode_only(self, profile: dict) -> int:
        """Decode-pool warm: the cache shape depends only on the session
        batch (caches are allocated at ``max_len`` regardless of prompt
        length), so one zero-filled donor cache per distinct batch shape
        covers every decode executable the peer has served."""
        dispatches = 0
        widths = list(profile.get("widths", []))
        batches = sorted({(shape[0], tuple(shape[2:]), dtype)
                          for shape, dtype in profile.get("prefill", [])})
        for bsz, tail, dtype in batches:
            cache = stage_init_cache(self.cfg, self.spec, bsz, self.max_len)
            step_x = jnp.zeros((bsz, 1) + tail, dtype=jnp.dtype(dtype))
            t = self.max_len - 1
            for w in widths:
                outs = self.decode_many([cache] * w, [step_x] * w, [t] * w)
                jax.block_until_ready(outs[0][0])
                dispatches += 1
            if not widths:
                out, _ = self.decode(cache, step_x, t)
                jax.block_until_ready(out)
                dispatches += 1
        self.stats["warmed_dispatches"] += dispatches
        return dispatches

"""StageExecutor: shared compile-reuse prefill/decode execution.

One instance serves one pipeline stage (all replicas of the stage share it,
and therefore share its jit cache) or the whole model as a single stage
(``ServeEngine``). It owns the three compute paths of the generative data
plane:

* :meth:`score`   — stateless teacher-forced forward (legacy submit path)
* :meth:`prefill` — build a per-session decode cache from a token history
* :meth:`decode` / :meth:`decode_many` — one autoregressive step for a
  single session, or one fused dispatch over N stacked sessions at
  *heterogeneous* positions (the continuous-batching hot path)

Compile reuse: jit already caches one executable per input shape; the
executor additionally right-pads prefill sequence lengths up to power-of-two
buckets so arbitrary history lengths (which re-prefill after a failure makes
common) hit a small set of executables instead of compiling per length.
Padding is only applied when every group in the stage slice uses a full
(non-ring, non-SSM) cache: causal masking makes right-padding invisible to
real positions there, while ring buffers would evict real keys and SSM
states would integrate the garbage tail.

``decode_many`` batches sessions by stacking their caches along a fresh
leading axis and ``vmap``-ing the single-step stage decode over it — each
session keeps its own position ``t``, so sessions that started at different
times still coalesce into one dispatch (same-``t``-only batching would never
converge once sessions drift).
"""
from __future__ import annotations

import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import DENSE, MOE, ModelConfig
from repro.statexfer.codec import PagedCachePayload, materialize_paged
from . import kvpool
from .envelope import ROLE_BOTH, ROLE_DECODE, ROLE_PREFILL
from .kvpool import PagedCacheHandle, PagePool
from .partition import (
    StageSpec,
    stage_decode,
    stage_forward,
    stage_init_cache,
    stage_params,
    stage_prefill,
    stage_verify,
    split_stages,
)


class StageExecutor:
    def __init__(self, cfg: ModelConfig, spec: StageSpec, sparams: Any, *,
                 max_len: int = 256, pad_seq: bool = True,
                 role: str = ROLE_BOTH, paged: bool = False,
                 page_size: int = 16,
                 pool_pages: int | None = None) -> None:
        self.cfg = cfg
        self.spec = spec
        self.sparams = sparams
        self.max_len = max_len
        #: which pool this executor serves: a ``prefill`` executor never
        #: compiles decode buckets, a ``decode`` executor never compiles the
        #: full prefill shape set — warm bootstrap replays only the role's
        #: slice of a peer's shape profile (see :meth:`warm`)
        self.role = role
        groups = [cfg.groups[gi] for gi, _, _ in spec.slices]
        #: every group uses a full (non-ring, non-SSM) attention cache —
        #: gates right-padding here and replay-idempotent snapshot restore
        #: in statexfer (rewriting position t with the same inputs is an
        #: exact no-op only for full caches)
        self.full_cache = all(
            g.kind in (DENSE, MOE) and g.window is None for g in groups)
        #: right-padding is a pure win only for full-cache attention stages
        self.pad_seq = pad_seq and self.full_cache
        #: paged KV mode: prefill installs the session cache into a shared
        #: PagePool and returns a page-table handle; decode_many stacks page
        #: tables instead of whole caches. Gated on full caches (page writes
        #: rely on decode touching exactly slot t) and page-aligned max_len.
        #: The contiguous path stays as the fallback/degrade target.
        self.paged = bool(paged) and self.full_cache \
            and max_len % page_size == 0
        self.page_size = page_size
        self.pool_pages = pool_pages or (4 * (max_len // page_size) + 1)
        self.pool: PagePool | None = None
        self._pool_init_lock = threading.Lock()
        #: flight-event sink (set by the server: FlightRecorder.record)
        self.on_event = None
        self._paged_many = None
        self._paged_widths_seen: set[int] = set()
        #: cached all-zeros donor caches for convoy pad slots, one per
        #: distinct cache leaf signature (built once, reused every pad)
        self._pad_caches: dict = {}
        tokens_in = spec.first

        self._score = jax.jit(
            lambda sp, x: stage_forward(cfg, spec, sp, x, tokens_in=tokens_in))
        self._prefill = jax.jit(
            lambda sp, x: stage_prefill(cfg, spec, sp, x, max_len,
                                        tokens_in=tokens_in))
        self._decode = jax.jit(
            lambda sp, c, x, t: stage_decode(cfg, spec, sp, c, x, t,
                                             tokens_in=tokens_in))
        # N sessions, each with its own cache and position, in one dispatch:
        # vmap over a stacked leading axis keeps every per-session batch dim
        # intact, so the inner stage_decode is byte-for-byte the single path.
        # Stacking N caches and splitting the N results back apart happens
        # INSIDE the jitted function — done on the host it costs dozens of
        # tiny dispatches per fused batch and erases the batching win.
        def _many(sp, caches, xs, ts):
            stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *caches)
            x = jnp.stack(xs)
            outs, new_stacked = jax.vmap(
                lambda c, xi, ti: stage_decode(cfg, spec, sp, c, xi, ti,
                                               tokens_in=tokens_in),
                in_axes=(0, 0, 0))(stacked, x, ts)
            n = len(caches)
            return (tuple(outs[i] for i in range(n)),
                    tuple(jax.tree.map(lambda l: l[i], new_stacked)
                          for i in range(n)))

        self._decode_many = jax.jit(_many)

        # Speculative verification: K stacked tokens per session (the
        # current token plus k draft proposals) integrated in ONE dispatch.
        # Same vmap-over-stacked-caches shape as ``_many``; the inner
        # per-session body is a single teacher-forced K-position sweep
        # (``stage_verify``) on full-cache stages — one weight pass where
        # K sequential decode steps would cost K — with the sequential
        # loop kept as the fallback for ring/SSM cache stages. K is
        # static (read from the input shape), so each (width, K) pair is
        # one fused executable. Last stage emits (B, K, V) logits; hidden
        # stages emit (B, K, D).
        full_cache = self.full_cache

        def _vmany(sp, caches, xs, ts):
            stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *caches)
            x = jnp.stack(xs)
            k = xs[0].shape[1]

            def one(c, xi, ti):
                if full_cache:
                    return stage_verify(cfg, spec, sp, c, xi, ti,
                                        tokens_in=tokens_in)
                ys = []
                for j in range(k):
                    y, c = stage_decode(cfg, spec, sp, c, xi[:, j:j + 1],
                                        ti + j, tokens_in=tokens_in)
                    ys.append(y)
                out = (jnp.stack(ys, axis=1) if ys[0].ndim == 2
                       else jnp.concatenate(ys, axis=1))
                return out, c

            outs, new_stacked = jax.vmap(one, in_axes=(0, 0, 0))(
                stacked, x, ts)
            n = len(caches)
            return (tuple(outs[i] for i in range(n)),
                    tuple(jax.tree.map(lambda l: l[i], new_stacked)
                          for i in range(n)))

        self._verify_many_fn = jax.jit(_vmany)
        self._paged_verify = None
        #: jitted draft rollouts, one per proposal budget k (the greedy
        #: argmax feedback loop makes k part of the program, not a shape)
        self._propose_fns: dict = {}
        self._propose_shapes_seen: set[tuple] = set()

        self.stats = {"score_calls": 0, "prefill_calls": 0,
                      "decode_batches": 0, "decode_steps": 0,
                      "first_call_compile_s": 0.0, "warmed_dispatches": 0,
                      "paged_decode_batches": 0, "paged_degrades": 0,
                      "verify_batches": 0, "verify_steps": 0,
                      "verify_tokens": 0, "propose_calls": 0,
                      "propose_tokens": 0}
        #: fused convoy widths already compiled (first-dispatch timing)
        self._widths_seen: set[int] = set()
        #: fused verify (width, K) shapes already compiled — part of the
        #: warm profile so bootstrap precompiles verify buckets too
        self._verify_widths_seen: set[tuple] = set()
        self._paged_verify_widths_seen: set[tuple] = set()
        #: post-bucketing prefill input shapes served so far — together with
        #: the widths this is the executor's *warm profile*: exactly the
        #: executables a same-role executor needs compiled (WarmBootstrap)
        self._prefill_shapes_seen: set[tuple] = set()

    @classmethod
    def for_model(cls, model, params, *, max_len: int = 256,
                  pad_seq: bool = True, **kw) -> "StageExecutor":
        """Whole model as a single stage (the standalone-engine case)."""
        spec = split_stages(model.cfg, 1)[0]
        return cls(model.cfg, spec, stage_params(model.cfg, params, spec),
                   max_len=max_len, pad_seq=pad_seq, **kw)

    # ------------------------------------------------------------------ shapes
    @staticmethod
    def _bucket(n: int) -> int:
        b = 8
        while b < n:
            b *= 2
        return b

    @staticmethod
    def _width_bucket(n: int) -> int:
        b = 2
        while b < n:
            b *= 2
        return b

    def _timed(self, key: str, fn, *args):
        """Record first-dispatch wall time (dominated by jit compile — the
        analogue of the paper's NCCL lazy-init dip) per executor."""
        first = self.stats[key] == 0
        t0 = time.monotonic()
        out = fn(self.sparams, *args)
        if first:
            jax.block_until_ready(out)
            self.stats["first_call_compile_s"] += time.monotonic() - t0
        self.stats[key] += 1
        return out

    # ----------------------------------------------------------------- compute
    def score(self, x: jax.Array) -> jax.Array:
        """Teacher-forced forward: tokens/hidden (B,S[,D]) -> full output."""
        return self._timed("score_calls", self._score, x)

    def prefill(self, x: jax.Array) -> tuple[jax.Array, Any]:
        """History (B,S[,D]) -> (output sliced back to S, session cache).

        In paged mode the contiguous prefill result is installed into the
        shared PagePool (leading full pages deduped against the prefix
        trie) and a :class:`~repro.serving.kvpool.PagedCacheHandle` is
        returned instead; on template mismatch or pool exhaustion the
        session simply keeps the contiguous cache."""
        x0, s = x, x.shape[1]
        if self.pad_seq:
            sp = min(self._bucket(s), self.max_len)
            if sp > s:
                pad = [(0, 0), (0, sp - s)] + [(0, 0)] * (x.ndim - 2)
                x = jnp.pad(x, pad)
        self._prefill_shapes_seen.add((tuple(x.shape), str(x.dtype)))
        out, cache = self._timed("prefill_calls", self._prefill, x)
        if out.shape[1] != s:
            out = out[:, :s]
        if self.paged:
            keys = kvpool.prefix_chunk_keys(x0, s, self.page_size)
            handle = self._ensure_pool().install_prefill(cache, s, keys)
            if handle is not None:
                return out, handle
        return out, cache

    def decode(self, cache: Any, x: jax.Array, t) -> tuple[jax.Array, Any]:
        """Single-session step: token/hidden (B,1[,D]) at position ``t``."""
        if isinstance(cache, PagedCacheHandle):
            return self._paged_decode_many([cache], [x], [t])[0]
        out, new_cache = self._timed(
            "decode_steps", self._decode, cache, x, jnp.int32(t))
        self.stats["decode_batches"] += 1
        return out, new_cache

    def decode_many(self, caches: list[Any], xs: list[jax.Array],
                    ts: list[int]) -> list[tuple[jax.Array, Any]]:
        """One fused dispatch over N sessions (own cache + position each).

        All ``xs`` must share one shape (same per-session batch); positions
        are free. Returns per-session (output, new_cache) in input order.
        Paged and contiguous sessions may mix in one convoy: each kind
        dispatches fused with its peers and the results merge in order.

        Convoy widths are bucketed to powers of two by duplicating lane 0's
        input shape (results discarded): otherwise every distinct width
        2..max compiles its own executable mid-serving, a compile stall per
        new width — the decode-path analogue of the prefill sequence
        buckets. Pad slots carry a cached all-zeros donor cache (built once
        per leaf signature), not a stacked copy of a real session's cache.
        """
        paged_idx = [i for i, c in enumerate(caches)
                     if isinstance(c, PagedCacheHandle)]
        if paged_idx:
            results: list = [None] * len(caches)
            contig_idx = [i for i in range(len(caches))
                          if not isinstance(caches[i], PagedCacheHandle)]
            paged_out = self._paged_decode_many(
                [caches[i] for i in paged_idx],
                [xs[i] for i in paged_idx], [ts[i] for i in paged_idx])
            for i, r in zip(paged_idx, paged_out):
                results[i] = r
            if contig_idx:
                contig_out = self.decode_many(
                    [caches[i] for i in contig_idx],
                    [xs[i] for i in contig_idx], [ts[i] for i in contig_idx])
                for i, r in zip(contig_idx, contig_out):
                    results[i] = r
            return results
        n = len(caches)
        if n == 1:
            return [self.decode(caches[0], xs[0], ts[0])]
        width = self._width_bucket(n)
        if width > n:
            pad = width - n
            caches = list(caches) + [self._pad_cache(caches[0])] * pad
            xs = list(xs) + [xs[0]] * pad
            ts = list(ts) + [0] * pad
        t = jnp.asarray(ts, jnp.int32)
        first = width not in self._widths_seen
        self._widths_seen.add(width)
        t0 = time.monotonic()
        outs, new_caches = self._decode_many(
            self.sparams, tuple(caches), tuple(xs), t)
        if first:
            jax.block_until_ready(outs)
            self.stats["first_call_compile_s"] += time.monotonic() - t0
        self.stats["decode_batches"] += 1
        self.stats["decode_steps"] += n
        return list(zip(outs[:n], new_caches[:n]))

    def _make_propose(self, k: int):
        cfg, spec, tokens_in = self.cfg, self.spec, self.spec.first
        full_cache = self.full_cache

        def _roll(sp, cache, xs, t):
            c = cache
            p = xs.shape[1]
            # integrate the P pending tokens in one teacher-forced sweep
            # where the cache layout allows it; the k-1 proposal steps
            # after it are inherently sequential (argmax feedback)
            if full_cache:
                y, c = stage_verify(cfg, spec, sp, c, xs, t,
                                    tokens_in=tokens_in)
                y = y[:, -1]
            else:
                y = None
                for j in range(p):
                    y, c = stage_decode(cfg, spec, sp, c, xs[:, j:j + 1],
                                        t + j, tokens_in=tokens_in)
            tok = jnp.argmax(y, axis=-1).astype(jnp.int32)[:, None]
            props = [tok]
            for i in range(1, k):
                y, c = stage_decode(cfg, spec, sp, c, props[-1],
                                    t + p + i - 1, tokens_in=tokens_in)
                props.append(
                    jnp.argmax(y, axis=-1).astype(jnp.int32)[:, None])
            return jnp.concatenate(props, axis=1), c

        return jax.jit(_roll)

    def propose_rollout(self, cache: Any, xs: jax.Array, t, k: int
                        ) -> tuple[jax.Array, Any]:
        """Draft-side speculative proposal in ONE dispatch.

        Integrates the P pending history tokens ``xs`` (B, P) at positions
        ``t .. t+P-1``, then rolls out ``k`` greedy proposals with argmax
        feedback — the whole integrate+propose loop is jit-fused (one
        executable per (P, k), both small and bounded by the speculation
        budget), so a proposal round costs one dispatch no matter how many
        tokens the last verify committed. Sequential single-token decodes
        here would cost P+k-1 dispatches per round and erase the
        speculative win at small-model scale. Full-model (logits-emitting)
        contiguous executors only — the draft pool never pages and never
        splits across stages. Returns (proposals (B, k) int32, new cache).
        """
        k = int(k)
        fn = self._propose_fns.get(k)
        if fn is None:
            fn = self._make_propose(k)
            self._propose_fns[k] = fn
        xs = jnp.asarray(xs, jnp.int32)
        key = (int(xs.shape[0]), int(xs.shape[1]), k)
        first = key not in self._propose_shapes_seen
        self._propose_shapes_seen.add(key)
        t0 = time.monotonic()
        props, new_cache = fn(self.sparams, cache, xs, jnp.int32(t))
        if first:
            jax.block_until_ready(props)
            self.stats["first_call_compile_s"] += time.monotonic() - t0
        self.stats["propose_calls"] += 1
        self.stats["propose_tokens"] += k
        return props, new_cache

    def _pad_cache(self, like: Any) -> Any:
        """All-zeros donor cache for convoy pad slots, cached per leaf
        signature: padding with ``caches[0]`` stacked a real session's
        cache bytes once per pad lane per microbatch for results nobody
        reads."""
        key = tuple((tuple(leaf.shape), str(leaf.dtype))
                    for leaf in jax.tree.leaves(like))
        donor = self._pad_caches.get(key)
        if donor is None:
            donor = jax.tree.map(jnp.zeros_like, like)
            self._pad_caches[key] = donor
        return donor

    # -------------------------------------------------------- spec. verify
    def verify_many(self, caches: list[Any], xs: list[jax.Array],
                    ts: list[int]) -> list[tuple[jax.Array, Any]]:
        """One fused *speculative verification* dispatch over N sessions.

        Each ``xs[i]`` stacks K tokens (the session's current committed
        token plus its k=K-1 draft proposals) — or K hidden-state columns
        on downstream stages — integrated at positions ``ts[i]..ts[i]+K-1``
        in one executable, exactly like ``decode_many`` but K-deep. The
        last stage returns (B, K, V) logits so the caller can judge the
        accepted prefix token-by-token (greedy parity is exact: position
        j's logits saw precisely the tokens 0..ts[i]+j-1). Rejected-suffix
        cache writes land in slots the decode validity mask never reads;
        paged handles additionally roll trailing pages back via
        :meth:`commit_verify`. Widths bucket to powers of two like decode
        convoys; each (width, K) pair compiles once.
        """
        paged_idx = [i for i, c in enumerate(caches)
                     if isinstance(c, PagedCacheHandle)]
        if paged_idx:
            results: list = [None] * len(caches)
            contig_idx = [i for i in range(len(caches))
                          if not isinstance(caches[i], PagedCacheHandle)]
            paged_out = self._paged_verify_many(
                [caches[i] for i in paged_idx],
                [xs[i] for i in paged_idx], [ts[i] for i in paged_idx])
            for i, r in zip(paged_idx, paged_out):
                results[i] = r
            if contig_idx:
                contig_out = self.verify_many(
                    [caches[i] for i in contig_idx],
                    [xs[i] for i in contig_idx], [ts[i] for i in contig_idx])
                for i, r in zip(contig_idx, contig_out):
                    results[i] = r
            return results
        n = len(caches)
        k = int(xs[0].shape[1])
        width = n if n == 1 else self._width_bucket(n)
        if width > n:
            pad = width - n
            caches = list(caches) + [self._pad_cache(caches[0])] * pad
            xs = list(xs) + [xs[0]] * pad
            ts = list(ts) + [0] * pad
        t = jnp.asarray(ts, jnp.int32)
        first = (width, k) not in self._verify_widths_seen
        self._verify_widths_seen.add((width, k))
        t0 = time.monotonic()
        outs, new_caches = self._verify_many_fn(
            self.sparams, tuple(caches), tuple(xs), t)
        if first:
            jax.block_until_ready(outs)
            self.stats["first_call_compile_s"] += time.monotonic() - t0
        self.stats["verify_batches"] += 1
        self.stats["verify_steps"] += n
        self.stats["verify_tokens"] += n * k
        return list(zip(outs[:n], new_caches[:n]))

    def commit_verify(self, cache: Any, length: int) -> Any:
        """Finalize a session's cache after verification accepted
        ``length`` total tokens (slots ``0..length-1`` live). Contiguous
        caches need nothing — rejected-suffix slots are overwritten before
        any read. Paged handles pop the trailing pages the speculative
        writes grew/COW'd past the accepted prefix (``PagePool.truncate``),
        so a low-acceptance session cannot leak pool occupancy."""
        if isinstance(cache, PagedCacheHandle):
            cache.pool.truncate(cache, int(length))
        return cache

    def _paged_verify_many(self, handles: list, xs: list,
                           ts: list) -> list[tuple[jax.Array, Any]]:
        """Paged speculative verification: prepare all K write slots per
        lane under the pool lock (growth + COW, so every written page is
        lane-exclusive), then one jitted dispatch that gathers each lane's
        cache, runs K decode steps, and scatters back the fixed-size page
        window covering the written slots. Any lane whose upkeep fails
        degrades to a contiguous cache and rides the contiguous verify."""
        n = len(handles)
        k = int(xs[0].shape[1])
        results: list = [None] * n
        caches = list(handles)
        live = []
        degraded = []
        pool = self._ensure_pool()
        # writes span at most W pages; a K too large for the per-seq table
        # window cannot dispatch paged at all
        w_need = (k + pool.page_size - 2) // pool.page_size + 1
        with pool.lock:
            for i, (h, t) in enumerate(zip(handles, ts)):
                ok = (h.pool is self.pool and w_need <= pool.pages_per_seq
                      and int(t) + k <= self.max_len)
                if ok:
                    for j in range(k):
                        if not self.pool.prepare_write(h, int(t) + j):
                            ok = False
                            break
                if ok:
                    live.append(i)
                else:
                    caches[i] = h.pool.materialize(h)
                    h.pool.release(h)
                    self.stats["paged_degrades"] += 1
                    degraded.append(i)
            if live:
                outs = self._dispatch_paged_verify(
                    [caches[i] for i in live], [xs[i] for i in live],
                    [ts[i] for i in live])
                for i, r in zip(live, outs):
                    results[i] = r
        if degraded:
            fallback = self.verify_many([caches[i] for i in degraded],
                                        [xs[i] for i in degraded],
                                        [ts[i] for i in degraded])
            for i, r in zip(degraded, fallback):
                results[i] = r
        return results

    def _dispatch_paged_verify(self, handles: list, xs: list,
                               ts: list) -> list[tuple[jax.Array, Any]]:
        pool = self.pool
        n = len(handles)
        k = int(xs[0].shape[1])
        width = n if n == 1 else self._width_bucket(n)
        tables = np.zeros((width, pool.pages_per_seq), np.int32)
        for i, h in enumerate(handles):
            tables[i, :len(h.pages)] = h.pages
        xs_p = list(xs) + [xs[0]] * (width - n)
        ts_p = list(ts) + [0] * (width - n)
        fn = self._get_paged_verify()
        first = (width, k) not in self._paged_verify_widths_seen
        self._paged_verify_widths_seen.add((width, k))
        t0 = time.monotonic()
        outs, new_leaves = fn(self.sparams, tuple(pool.leaves),
                              jnp.asarray(tables),
                              tuple(xs_p), jnp.asarray(ts_p, jnp.int32))
        if first:
            jax.block_until_ready(outs)
            self.stats["first_call_compile_s"] += time.monotonic() - t0
        pool.leaves = list(new_leaves)
        for h, t in zip(handles, ts):
            h.length = max(h.length, int(t) + k)
        self.stats["verify_batches"] += 1
        self.stats["verify_steps"] += n
        self.stats["verify_tokens"] += n * k
        self.stats["paged_decode_batches"] += 1
        return [(outs[i], handles[i]) for i in range(n)]

    def _get_paged_verify(self):
        if self._paged_verify is None:
            cfg, spec, pool = self.cfg, self.spec, self.pool
            tokens_in = spec.first
            axes = tuple(pool.axes)
            page = pool.page_size
            pps = pool.pages_per_seq
            structure = jax.tree.structure(pool.skeleton)

            def _many_pv(sp, pool_leaves, tables, xs, ts):
                def one(table, x, t):
                    leaves = kvpool.gather_pages(pool_leaves, axes, table,
                                                 page)
                    cache = jax.tree.unflatten(structure, leaves)
                    kk = x.shape[1]
                    # paged executors are full-cache by construction, so
                    # the K positions verify in one teacher-forced sweep
                    out, cache = stage_verify(cfg, spec, sp, cache, x, t,
                                              tokens_in=tokens_in)
                    new_leaves = structure.flatten_up_to(cache)
                    # fixed page window covering every written slot; when
                    # the clamp pulls the window start below t//page the
                    # extra leading pages scatter back bit-identical
                    # gathered content (a value-level no-op even for
                    # shared pages)
                    w = (kk + page - 2) // page + 1
                    li0 = jnp.minimum(t // page, pps - w)
                    pgs = []
                    for leaf, ax in zip(new_leaves, axes):
                        pgs.append(jnp.stack([
                            jax.lax.dynamic_slice_in_dim(
                                leaf, (li0 + wi) * page, page, axis=ax)
                            for wi in range(w)]))
                    phys = jax.lax.dynamic_slice_in_dim(table, li0, w)
                    return out, pgs, phys

                x = jnp.stack(xs)
                outs, pgs, phys = jax.vmap(one, in_axes=(0, 0, 0))(
                    tables, x, ts)
                # written pages are lane-exclusive (prepare_write COW'd
                # them); unwritten window pages rewrite their own bytes;
                # zero table entries and pad lanes land on scratch page 0
                flat_phys = phys.reshape(-1)
                new_pool = tuple(
                    leaf.at[flat_phys].set(
                        pg.reshape((-1,) + pg.shape[2:]))
                    for leaf, pg in zip(pool_leaves, pgs))
                return outs, new_pool

            self._paged_verify = jax.jit(_many_pv)
        return self._paged_verify

    # ------------------------------------------------------------ paged mode
    def _ensure_pool(self) -> PagePool:
        with self._pool_init_lock:
            if self.pool is None:
                self.pool = PagePool(
                    self.cfg, self.spec, max_len=self.max_len,
                    page_size=self.page_size, num_pages=self.pool_pages,
                    on_event=self._pool_event)
        return self.pool

    def _pool_event(self, kind: str, **fields) -> None:
        if self.on_event is not None:
            self.on_event(kind, **fields)

    def adopt_cache(self, cache: Any) -> Any:
        """Normalize an installed session cache for this executor. Paged
        wire payloads enter the pool directly (page-granular restore, full
        prefix pages re-shared via the trie); without a usable pool they
        materialize to a contiguous cache. Handles and contiguous caches
        pass through."""
        if isinstance(cache, PagedCachePayload):
            if self.paged:
                handle = self._ensure_pool().install_payload(cache)
                if handle is not None:
                    return handle
            return materialize_paged(cache)
        return cache

    def release_cache(self, cache: Any) -> None:
        """Return a dropped session's pool pages (no-op for contiguous)."""
        if isinstance(cache, PagedCacheHandle):
            cache.pool.release(cache)

    def _paged_decode_many(self, handles: list, xs: list,
                           ts: list) -> list[tuple[jax.Array, Any]]:
        """Fused decode over paged sessions: host-side page-table upkeep
        (growth + copy-on-write), then one jitted dispatch that gathers
        each lane's cache through its page table and scatters back only the
        page containing its written slot. A session whose upkeep fails
        (pool exhausted) degrades to a contiguous cache and rides the
        contiguous path — never crashes."""
        n = len(handles)
        results: list = [None] * n
        caches = list(handles)
        live = []
        degraded = []
        # hold the pool lock across upkeep + dispatch + leaves writeback:
        # replicas share this executor and decode on worker threads, and a
        # concurrent dispatch reading the same pool arrays would lose this
        # one's page writes when it stores its own new arrays back
        with self._ensure_pool().lock:
            for i, (h, t) in enumerate(zip(handles, ts)):
                ok = (self.pool is not None and h.pool is self.pool
                      and self.pool.prepare_write(h, int(t)))
                if ok:
                    live.append(i)
                else:
                    caches[i] = h.pool.materialize(h)
                    h.pool.release(h)
                    self.stats["paged_degrades"] += 1
                    degraded.append(i)
            if live:
                outs = self._dispatch_paged([caches[i] for i in live],
                                            [xs[i] for i in live],
                                            [ts[i] for i in live])
                for i, r in zip(live, outs):
                    results[i] = r
        if degraded:
            fallback = self.decode_many([caches[i] for i in degraded],
                                        [xs[i] for i in degraded],
                                        [ts[i] for i in degraded])
            for i, r in zip(degraded, fallback):
                results[i] = r
        return results

    def _dispatch_paged(self, handles: list, xs: list,
                        ts: list) -> list[tuple[jax.Array, Any]]:
        pool = self.pool
        n = len(handles)
        width = n if n == 1 else self._width_bucket(n)
        tables = np.zeros((width, pool.pages_per_seq), np.int32)
        for i, h in enumerate(handles):
            tables[i, :len(h.pages)] = h.pages
        # pad lanes: all-zero tables target the reserved scratch page — the
        # gather reads garbage nobody looks at, the writeback lands on page 0
        xs_p = list(xs) + [xs[0]] * (width - n)
        ts_p = list(ts) + [0] * (width - n)
        fn = self._get_paged_many()
        first = width not in self._paged_widths_seen
        self._paged_widths_seen.add(width)
        t0 = time.monotonic()
        outs, new_leaves = fn(self.sparams, tuple(pool.leaves),
                              jnp.asarray(tables),
                              tuple(xs_p), jnp.asarray(ts_p, jnp.int32))
        if first:
            jax.block_until_ready(outs)
            self.stats["first_call_compile_s"] += time.monotonic() - t0
        pool.leaves = list(new_leaves)
        for h, t in zip(handles, ts):
            h.length = max(h.length, int(t) + 1)
        self.stats["decode_batches"] += 1
        self.stats["decode_steps"] += n
        self.stats["paged_decode_batches"] += 1
        return [(outs[i], handles[i]) for i in range(n)]

    def _get_paged_many(self):
        if self._paged_many is None:
            cfg, spec, pool = self.cfg, self.spec, self.pool
            tokens_in = spec.first
            axes = tuple(pool.axes)
            page = pool.page_size
            structure = jax.tree.structure(pool.skeleton)

            def _many_paged(sp, pool_leaves, tables, xs, ts):
                def one(table, x, t):
                    leaves = kvpool.gather_pages(pool_leaves, axes, table,
                                                 page)
                    cache = jax.tree.unflatten(structure, leaves)
                    out, new_cache = stage_decode(cfg, spec, sp, cache, x, t,
                                                  tokens_in=tokens_in)
                    new_leaves = structure.flatten_up_to(new_cache)
                    li = t // page
                    pg = [jax.lax.dynamic_slice_in_dim(
                        leaf, li * page, page, axis=ax)
                        for leaf, ax in zip(new_leaves, axes)]
                    return out, pg, table[li]

                x = jnp.stack(xs)
                outs, pgs, phys = jax.vmap(one, in_axes=(0, 0, 0))(
                    tables, x, ts)
                # distinct lanes own distinct physical pages (prepare_write
                # guarantees exclusivity); pad lanes all hit scratch page 0
                new_pool = tuple(
                    leaf.at[phys].set(pg)
                    for leaf, pg in zip(pool_leaves, pgs))
                return outs, new_pool

            self._paged_many = jax.jit(_many_paged)
        return self._paged_many

    # ---------------------------------------------------------- warm profile
    def warm_profile(self) -> dict:
        """What a same-role executor must compile to serve like this one:
        the bucketed prefill shapes served so far and the fused decode
        convoy widths dispatched so far (WarmBootstrap ships this from a
        peer replica to a fresh one)."""
        return {"prefill": sorted(self._prefill_shapes_seen),
                "widths": sorted(self._widths_seen),
                "verify": sorted(self._verify_widths_seen),
                "propose": sorted(self._propose_shapes_seen)}

    def obs_stats(self) -> dict:
        """Flat numeric view of the executor for the metrics export
        surface: dispatch counters plus how much of the jit cache the
        served traffic has populated (warm-profile cardinality)."""
        out = dict(self.stats)
        out["prefill_shapes_compiled"] = len(self._prefill_shapes_seen)
        out["decode_widths_compiled"] = len(self._widths_seen)
        out["paged_widths_compiled"] = len(self._paged_widths_seen)
        out["verify_widths_compiled"] = (len(self._verify_widths_seen)
                                        + len(self._paged_verify_widths_seen))
        out["propose_shapes_compiled"] = len(self._propose_shapes_seen)
        if self.pool is not None:
            out.update(self.pool.stats())
        return out

    def pool_stats(self) -> dict:
        """Page-pool gauges for the kvpool metrics group ({} when the pool
        has not been built — no paged session served yet)."""
        return self.pool.stats() if self.pool is not None else {}

    def warm(self, profile: dict) -> int:
        """Replay a peer's warm profile with dummy inputs so every listed
        executable is compiled before real traffic arrives. Returns the
        number of warm dispatches issued. Dummy results are discarded; the
        dispatches land in the shared jit cache, which is the entire point.

        Role filtering (disaggregated pools): a ``prefill`` executor replays
        only the prefill shape set — its replicas never decode, so compiling
        decode convoy widths would burn warm time on executables the jit
        cache never serves. A ``decode`` executor skips prefill compiles
        entirely: its caches arrive pre-built over the handoff wire, so the
        donor caches for width warmup are constructed host-side with
        :func:`stage_init_cache` (an allocation, not a compile) — one per
        distinct batch shape instead of one prefill executable per sequence
        bucket. Either way the role's warm bootstrap is strictly cheaper
        than the colocated profile replay.
        """
        if self.role == ROLE_DECODE:
            return self._warm_decode_only(profile)
        dispatches = 0
        widths = (list(profile.get("widths", []))
                  if self.role != ROLE_PREFILL else [])
        verifies = (list(profile.get("verify", []))
                    if self.role != ROLE_PREFILL else [])
        proposes = (list(profile.get("propose", []))
                    if self.role != ROLE_PREFILL else [])
        for shape, dtype in profile.get("prefill", []):
            x = jnp.zeros(shape, dtype=jnp.dtype(dtype))
            # go through the jitted callable directly: prefill() would
            # re-bucket (already-bucketed shapes pass through unchanged) and
            # pollute the first-call timing stats
            out, cache = self._prefill(self.sparams, x)
            jax.block_until_ready(out)
            self._prefill_shapes_seen.add((tuple(shape), str(dtype)))
            dispatches += 1
            if self.role == ROLE_PREFILL:
                continue
            # decode warmup needs a live cache of the right batch; reuse the
            # one this prefill just built
            step_x = jnp.zeros((shape[0], 1) + tuple(shape[2:]),
                               dtype=jnp.dtype(dtype))
            t = min(shape[1], self.max_len - 1)
            dispatches += self._warm_widths(cache, step_x, t, widths,
                                            verifies, proposes)
        self.stats["warmed_dispatches"] += dispatches
        return dispatches

    def _warm_widths(self, cache, step_x, t, widths, verifies=(),
                     proposes=()) -> int:
        """Replay the decode convoy widths (and the verify (width, K)
        buckets) against one live cache — the shared tail of both warm
        paths. Falls back to a single-step decode when the peer never
        dispatched a fused convoy."""
        dispatches = 0
        for w in widths:
            outs = self.decode_many([cache] * w, [step_x] * w, [t] * w)
            jax.block_until_ready(outs[0][0])
            dispatches += 1
        if not widths:
            out, _ = self.decode(cache, step_x, t)
            jax.block_until_ready(out)
            dispatches += 1
        for w, k in verifies:
            vt = min(t, self.max_len - k)
            if vt < 0:
                continue
            vx = jnp.concatenate([step_x] * k, axis=1)
            outs = self.verify_many([cache] * w, [vx] * w, [vt] * w)
            jax.block_until_ready(outs[0][0])
            dispatches += 1
        for entry in proposes:
            _, p, kk = entry     # (batch, pending, k) — replayed at the
            pt = min(t, self.max_len - p - kk + 1)   # cache's own batch
            if pt < 0:
                continue
            px = jnp.concatenate([step_x] * p, axis=1)
            props, _ = self.propose_rollout(cache, px, pt, kk)
            jax.block_until_ready(props)
            dispatches += 1
        return dispatches

    def _warm_decode_only(self, profile: dict) -> int:
        """Decode-pool warm: the cache shape depends only on the session
        batch (caches are allocated at ``max_len`` regardless of prompt
        length), so one zero-filled donor cache per distinct batch shape
        covers every decode executable the peer has served."""
        dispatches = 0
        widths = list(profile.get("widths", []))
        verifies = list(profile.get("verify", []))
        batches = sorted({(shape[0], tuple(shape[2:]), dtype)
                          for shape, dtype in profile.get("prefill", [])})
        for bsz, tail, dtype in batches:
            cache = stage_init_cache(self.cfg, self.spec, bsz, self.max_len)
            step_x = jnp.zeros((bsz, 1) + tail, dtype=jnp.dtype(dtype))
            t = self.max_len - 1
            dispatches += self._warm_widths(cache, step_x, t, widths,
                                            verifies)
        self.stats["warmed_dispatches"] += dispatches
        return dispatches

"""StageExecutor: shared compile-reuse prefill/decode execution.

One instance serves one pipeline stage (all replicas of the stage share it,
and therefore share its jit cache) or the whole model as a single stage
(``ServeEngine``). It owns the three compute paths of the generative data
plane:

* :meth:`score`   — stateless teacher-forced forward (legacy submit path)
* :meth:`prefill` — build a per-session decode cache from a token history
* :meth:`decode` / :meth:`decode_many` — one autoregressive step for a
  single session, or one fused dispatch over N stacked sessions at
  *heterogeneous* positions (the continuous-batching hot path)

Compile reuse: jit already caches one executable per input shape; the
executor additionally right-pads prefill sequence lengths up to power-of-two
buckets so arbitrary history lengths (which re-prefill after a failure makes
common) hit a small set of executables instead of compiling per length.
Padding is only applied when every group in the stage slice uses a full
(non-ring, non-SSM) cache: causal masking makes right-padding invisible to
real positions there, while ring buffers would evict real keys and SSM
states would integrate the garbage tail.

``decode_many`` batches sessions by stacking their caches along a fresh
leading axis and ``vmap``-ing the single-step stage decode over it — each
session keeps its own position ``t``, so sessions that started at different
times still coalesce into one dispatch (same-``t``-only batching would never
converge once sessions drift).
"""
from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import DENSE, MOE, ModelConfig
from .envelope import ROLE_BOTH, ROLE_DECODE, ROLE_PREFILL
from .partition import (
    StageSpec,
    stage_decode,
    stage_forward,
    stage_init_cache,
    stage_params,
    stage_prefill,
    split_stages,
)


class StageExecutor:
    def __init__(self, cfg: ModelConfig, spec: StageSpec, sparams: Any, *,
                 max_len: int = 256, pad_seq: bool = True,
                 role: str = ROLE_BOTH) -> None:
        self.cfg = cfg
        self.spec = spec
        self.sparams = sparams
        self.max_len = max_len
        #: which pool this executor serves: a ``prefill`` executor never
        #: compiles decode buckets, a ``decode`` executor never compiles the
        #: full prefill shape set — warm bootstrap replays only the role's
        #: slice of a peer's shape profile (see :meth:`warm`)
        self.role = role
        groups = [cfg.groups[gi] for gi, _, _ in spec.slices]
        #: every group uses a full (non-ring, non-SSM) attention cache —
        #: gates right-padding here and replay-idempotent snapshot restore
        #: in statexfer (rewriting position t with the same inputs is an
        #: exact no-op only for full caches)
        self.full_cache = all(
            g.kind in (DENSE, MOE) and g.window is None for g in groups)
        #: right-padding is a pure win only for full-cache attention stages
        self.pad_seq = pad_seq and self.full_cache
        tokens_in = spec.first

        self._score = jax.jit(
            lambda sp, x: stage_forward(cfg, spec, sp, x, tokens_in=tokens_in))
        self._prefill = jax.jit(
            lambda sp, x: stage_prefill(cfg, spec, sp, x, max_len,
                                        tokens_in=tokens_in))
        self._decode = jax.jit(
            lambda sp, c, x, t: stage_decode(cfg, spec, sp, c, x, t,
                                             tokens_in=tokens_in))
        # N sessions, each with its own cache and position, in one dispatch:
        # vmap over a stacked leading axis keeps every per-session batch dim
        # intact, so the inner stage_decode is byte-for-byte the single path.
        # Stacking N caches and splitting the N results back apart happens
        # INSIDE the jitted function — done on the host it costs dozens of
        # tiny dispatches per fused batch and erases the batching win.
        def _many(sp, caches, xs, ts):
            stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *caches)
            x = jnp.stack(xs)
            outs, new_stacked = jax.vmap(
                lambda c, xi, ti: stage_decode(cfg, spec, sp, c, xi, ti,
                                               tokens_in=tokens_in),
                in_axes=(0, 0, 0))(stacked, x, ts)
            n = len(caches)
            return (tuple(outs[i] for i in range(n)),
                    tuple(jax.tree.map(lambda l: l[i], new_stacked)
                          for i in range(n)))

        self._decode_many = jax.jit(_many)

        self.stats = {"score_calls": 0, "prefill_calls": 0,
                      "decode_batches": 0, "decode_steps": 0,
                      "first_call_compile_s": 0.0, "warmed_dispatches": 0}
        #: fused convoy widths already compiled (first-dispatch timing)
        self._widths_seen: set[int] = set()
        #: post-bucketing prefill input shapes served so far — together with
        #: the widths this is the executor's *warm profile*: exactly the
        #: executables a same-role executor needs compiled (WarmBootstrap)
        self._prefill_shapes_seen: set[tuple] = set()

    @classmethod
    def for_model(cls, model, params, *, max_len: int = 256,
                  pad_seq: bool = True) -> "StageExecutor":
        """Whole model as a single stage (the standalone-engine case)."""
        spec = split_stages(model.cfg, 1)[0]
        return cls(model.cfg, spec, stage_params(model.cfg, params, spec),
                   max_len=max_len, pad_seq=pad_seq)

    # ------------------------------------------------------------------ shapes
    @staticmethod
    def _bucket(n: int) -> int:
        b = 8
        while b < n:
            b *= 2
        return b

    @staticmethod
    def _width_bucket(n: int) -> int:
        b = 2
        while b < n:
            b *= 2
        return b

    def _timed(self, key: str, fn, *args):
        """Record first-dispatch wall time (dominated by jit compile — the
        analogue of the paper's NCCL lazy-init dip) per executor."""
        first = self.stats[key] == 0
        t0 = time.monotonic()
        out = fn(self.sparams, *args)
        if first:
            jax.block_until_ready(out)
            self.stats["first_call_compile_s"] += time.monotonic() - t0
        self.stats[key] += 1
        return out

    # ----------------------------------------------------------------- compute
    def score(self, x: jax.Array) -> jax.Array:
        """Teacher-forced forward: tokens/hidden (B,S[,D]) -> full output."""
        return self._timed("score_calls", self._score, x)

    def prefill(self, x: jax.Array) -> tuple[jax.Array, Any]:
        """History (B,S[,D]) -> (output sliced back to S, session cache)."""
        s = x.shape[1]
        if self.pad_seq:
            sp = min(self._bucket(s), self.max_len)
            if sp > s:
                pad = [(0, 0), (0, sp - s)] + [(0, 0)] * (x.ndim - 2)
                x = jnp.pad(x, pad)
        self._prefill_shapes_seen.add((tuple(x.shape), str(x.dtype)))
        out, cache = self._timed("prefill_calls", self._prefill, x)
        if out.shape[1] != s:
            out = out[:, :s]
        return out, cache

    def decode(self, cache: Any, x: jax.Array, t) -> tuple[jax.Array, Any]:
        """Single-session step: token/hidden (B,1[,D]) at position ``t``."""
        out, new_cache = self._timed(
            "decode_steps", self._decode, cache, x, jnp.int32(t))
        self.stats["decode_batches"] += 1
        return out, new_cache

    def decode_many(self, caches: list[Any], xs: list[jax.Array],
                    ts: list[int]) -> list[tuple[jax.Array, Any]]:
        """One fused dispatch over N sessions (own cache + position each).

        All ``xs`` must share one shape (same per-session batch); positions
        are free. Returns per-session (output, new_cache) in input order.

        Convoy widths are bucketed to powers of two by duplicating lane 0
        (results discarded): otherwise every distinct width 2..max compiles
        its own executable mid-serving, a compile stall per new width — the
        decode-path analogue of the prefill sequence buckets.
        """
        n = len(caches)
        if n == 1:
            return [self.decode(caches[0], xs[0], ts[0])]
        width = self._width_bucket(n)
        if width > n:
            pad = width - n
            caches = list(caches) + [caches[0]] * pad
            xs = list(xs) + [xs[0]] * pad
            ts = list(ts) + [ts[0]] * pad
        t = jnp.asarray(ts, jnp.int32)
        first = width not in self._widths_seen
        self._widths_seen.add(width)
        t0 = time.monotonic()
        outs, new_caches = self._decode_many(
            self.sparams, tuple(caches), tuple(xs), t)
        if first:
            jax.block_until_ready(outs)
            self.stats["first_call_compile_s"] += time.monotonic() - t0
        self.stats["decode_batches"] += 1
        self.stats["decode_steps"] += n
        return list(zip(outs[:n], new_caches[:n]))

    # ---------------------------------------------------------- warm profile
    def warm_profile(self) -> dict:
        """What a same-role executor must compile to serve like this one:
        the bucketed prefill shapes served so far and the fused decode
        convoy widths dispatched so far (WarmBootstrap ships this from a
        peer replica to a fresh one)."""
        return {"prefill": sorted(self._prefill_shapes_seen),
                "widths": sorted(self._widths_seen)}

    def obs_stats(self) -> dict:
        """Flat numeric view of the executor for the metrics export
        surface: dispatch counters plus how much of the jit cache the
        served traffic has populated (warm-profile cardinality)."""
        out = dict(self.stats)
        out["prefill_shapes_compiled"] = len(self._prefill_shapes_seen)
        out["decode_widths_compiled"] = len(self._widths_seen)
        return out

    def warm(self, profile: dict) -> int:
        """Replay a peer's warm profile with dummy inputs so every listed
        executable is compiled before real traffic arrives. Returns the
        number of warm dispatches issued. Dummy results are discarded; the
        dispatches land in the shared jit cache, which is the entire point.

        Role filtering (disaggregated pools): a ``prefill`` executor replays
        only the prefill shape set — its replicas never decode, so compiling
        decode convoy widths would burn warm time on executables the jit
        cache never serves. A ``decode`` executor skips prefill compiles
        entirely: its caches arrive pre-built over the handoff wire, so the
        donor caches for width warmup are constructed host-side with
        :func:`stage_init_cache` (an allocation, not a compile) — one per
        distinct batch shape instead of one prefill executable per sequence
        bucket. Either way the role's warm bootstrap is strictly cheaper
        than the colocated profile replay.
        """
        if self.role == ROLE_DECODE:
            return self._warm_decode_only(profile)
        dispatches = 0
        widths = (list(profile.get("widths", []))
                  if self.role != ROLE_PREFILL else [])
        for shape, dtype in profile.get("prefill", []):
            x = jnp.zeros(shape, dtype=jnp.dtype(dtype))
            # go through the jitted callable directly: prefill() would
            # re-bucket (already-bucketed shapes pass through unchanged) and
            # pollute the first-call timing stats
            out, cache = self._prefill(self.sparams, x)
            jax.block_until_ready(out)
            self._prefill_shapes_seen.add((tuple(shape), str(dtype)))
            dispatches += 1
            if self.role == ROLE_PREFILL:
                continue
            # decode warmup needs a live cache of the right batch; reuse the
            # one this prefill just built
            step_x = jnp.zeros((shape[0], 1) + tuple(shape[2:]),
                               dtype=jnp.dtype(dtype))
            t = min(shape[1], self.max_len - 1)
            for w in widths:
                outs = self.decode_many([cache] * w, [step_x] * w, [t] * w)
                jax.block_until_ready(outs[0][0])
                dispatches += 1
            if not widths:
                out2, _ = self.decode(cache, step_x, t)
                jax.block_until_ready(out2)
                dispatches += 1
        self.stats["warmed_dispatches"] += dispatches
        return dispatches

    def _warm_decode_only(self, profile: dict) -> int:
        """Decode-pool warm: the cache shape depends only on the session
        batch (caches are allocated at ``max_len`` regardless of prompt
        length), so one zero-filled donor cache per distinct batch shape
        covers every decode executable the peer has served."""
        dispatches = 0
        widths = list(profile.get("widths", []))
        batches = sorted({(shape[0], tuple(shape[2:]), dtype)
                          for shape, dtype in profile.get("prefill", [])})
        for bsz, tail, dtype in batches:
            cache = stage_init_cache(self.cfg, self.spec, bsz, self.max_len)
            step_x = jnp.zeros((bsz, 1) + tail, dtype=jnp.dtype(dtype))
            t = self.max_len - 1
            for w in widths:
                outs = self.decode_many([cache] * w, [step_x] * w, [t] * w)
                jax.block_until_ready(outs[0][0])
                dispatches += 1
            if not widths:
                out, _ = self.decode(cache, step_x, t)
                jax.block_until_ready(out)
                dispatches += 1
        self.stats["warmed_dispatches"] += dispatches
        return dispatches

"""ModelRegistry: which models exist, and where they are resident.

One elastic pool, many models — the sharpest form of the paper's "workloads
shift while process groups cannot" premise is *which model* is hot.
One-model-one-server strands replicas exactly the way fixed process groups
strand workers (the kserve multi-model observation), so the registry turns
model residency into a first-class, refcounted, evictable resource:

* **entries** — ``register(name, model, params)`` records a servable model:
  its config and full parameter pytree (the "store" a cold load reads when
  no resident peer can stream the weights). ``get`` misses raise with the
  known names and a closest-match suggestion, same discipline as
  ``repro.configs.get_config``.
* **residency** — a replica *hosts* a set of models. ``load``/``unload``
  track which, in LRU order (``touch`` on every dispatch). Residency is
  the unit the router routes on and the LOAD/UNLOAD/SWAP protocol moves.
* **refcounts** — every open session holds a reference on its (replica,
  model) residency (``acquire``/``release``). ``unload`` refuses while
  sessions are open — evicting the weights under a live KV cache would
  turn the next decode step into garbage — and LRU eviction (when a load
  would exceed ``max_resident``) only ever considers refcount-zero
  residencies, raising :class:`ResidencyError` when nothing is evictable.

The registry is pure bookkeeping — no weights move here. The wire legs
(streaming stage weights from a resident peer, swap choreography, router
tag updates, session migration off an unloading replica) live in
``statexfer/bootstrap.py`` and ``PipelineServer.load_model``/
``unload_model``/``swap_model``; layering them over one bookkeeper keeps
"who may evict what" decidable in one place.
"""
from __future__ import annotations

import dataclasses
import difflib
import itertools
from typing import Any, Optional


class ResidencyError(RuntimeError):
    """A load/unload/eviction that would violate residency invariants:
    unloading (or LRU-evicting) a model that open sessions still pin, or
    loading past ``max_resident`` with nothing evictable."""


@dataclasses.dataclass
class ModelEntry:
    """One servable model: config + the parameter store cold loads read."""

    name: str
    model: Any                 # built model (carries .cfg)
    params: Any                # full parameter pytree ("the store")
    #: lifetime counters (dashboards; the wire-leg counters live on the
    #: bootstrap protocol driver)
    loads_total: int = 0
    unloads_total: int = 0

    @property
    def cfg(self):
        return self.model.cfg


class ModelRegistry:
    def __init__(self, *, max_resident: Optional[int] = None) -> None:
        #: max models resident per replica; None = unbounded (the
        #: in-process simulation has no real HBM to run out of, but the
        #: eviction discipline must exist for the real deployment)
        self.max_resident = max_resident
        self.entries: dict[str, ModelEntry] = {}
        #: worker -> {model name -> LRU stamp}; insertion + touch order
        self._resident: dict[str, dict[str, int]] = {}
        #: (worker, model) -> open-session refcount
        self._refs: dict[tuple[str, str], int] = {}
        self._clock = itertools.count(1)
        self.loads_total = 0
        self.unloads_total = 0
        self.evictions_total = 0
        self.eviction_refusals = 0

    # ------------------------------------------------------------- entries
    def register(self, name: str, model: Any, params: Any) -> ModelEntry:
        entry = ModelEntry(name=name, model=model, params=params)
        self.entries[name] = entry
        return entry

    def get(self, name: str) -> ModelEntry:
        entry = self.entries.get(name)
        if entry is None:
            known = sorted(self.entries)
            hint = difflib.get_close_matches(name, known, n=1)
            raise KeyError(
                f"unknown model {name!r}; registered: {known}"
                + (f" — did you mean {hint[0]!r}?" if hint else ""))
        return entry

    def names(self) -> list[str]:
        return sorted(self.entries)

    # ------------------------------------------------------------ residency
    def resident(self, worker_id: str) -> list[str]:
        """Models resident on ``worker_id``, least-recently-used first."""
        r = self._resident.get(worker_id, {})
        return [m for m, _ in sorted(r.items(), key=lambda kv: kv[1])]

    def is_resident(self, worker_id: str, name: str) -> bool:
        return name in self._resident.get(worker_id, {})

    def refcount(self, worker_id: str, name: str) -> int:
        return self._refs.get((worker_id, name), 0)

    def touch(self, worker_id: str, name: str) -> None:
        """LRU update: this residency just served traffic."""
        r = self._resident.get(worker_id)
        if r is not None and name in r:
            r[name] = next(self._clock)

    def load(self, worker_id: str, name: str) -> list[str]:
        """Mark ``name`` resident on ``worker_id``; returns the models LRU-
        evicted to make room (the caller must complete their unload —
        router untag, executor release). Raises :class:`ResidencyError`
        when over ``max_resident`` with nothing evictable: every other
        residency is pinned by open sessions."""
        self.get(name)                      # must be registered
        r = self._resident.setdefault(worker_id, {})
        if name in r:
            r[name] = next(self._clock)
            return []
        evicted: list[str] = []
        if self.max_resident is not None:
            while len(r) >= self.max_resident:
                victim = next(
                    (m for m, _ in sorted(r.items(), key=lambda kv: kv[1])
                     if self.refcount(worker_id, m) == 0), None)
                if victim is None:
                    self.eviction_refusals += 1
                    raise ResidencyError(
                        f"cannot load {name!r} on {worker_id}: "
                        f"{len(r)}/{self.max_resident} resident models all "
                        f"pinned by open sessions ({sorted(r)})")
                del r[victim]
                self._refs.pop((worker_id, victim), None)
                self.evictions_total += 1
                ent = self.entries.get(victim)
                if ent is not None:
                    ent.unloads_total += 1
                evicted.append(victim)
        r[name] = next(self._clock)
        self.loads_total += 1
        self.entries[name].loads_total += 1
        return evicted

    def unload(self, worker_id: str, name: str, *,
               force: bool = False) -> None:
        """Retire a residency. Refuses (``ResidencyError``) while open
        sessions still reference it unless ``force`` — forced unload is
        the teardown/kill path where the sessions are already lost."""
        r = self._resident.get(worker_id, {})
        if name not in r:
            return
        refs = self.refcount(worker_id, name)
        if refs > 0 and not force:
            self.eviction_refusals += 1
            raise ResidencyError(
                f"refusing to unload {name!r} from {worker_id}: "
                f"{refs} open session(s) pin it")
        del r[name]
        self._refs.pop((worker_id, name), None)
        self.unloads_total += 1
        ent = self.entries.get(name)
        if ent is not None:
            ent.unloads_total += 1

    def drop_worker(self, worker_id: str) -> None:
        """Replica teardown: all its residencies and refs go with it."""
        self._resident.pop(worker_id, None)
        for key in [k for k in self._refs if k[0] == worker_id]:
            del self._refs[key]

    # ------------------------------------------------------------ refcounts
    def acquire(self, worker_id: str, name: str) -> None:
        """One open session now pins (worker, model)."""
        self._refs[(worker_id, name)] = self.refcount(worker_id, name) + 1
        self.touch(worker_id, name)

    def release(self, worker_id: str, name: str) -> None:
        key = (worker_id, name)
        n = self._refs.get(key, 0)
        if n <= 1:
            self._refs.pop(key, None)
        else:
            self._refs[key] = n - 1

    # ------------------------------------------------------------ reporting
    def resident_counts(self) -> dict[str, int]:
        """model -> number of replicas it is resident on (routing/metrics
        view: a model with zero resident replicas cannot serve)."""
        out = {name: 0 for name in self.entries}
        for r in self._resident.values():
            for m in r:
                if m in out:
                    out[m] += 1
        return out

    def stats(self) -> dict:
        return {
            "models_registered": len(self.entries),
            "loads_total": self.loads_total,
            "unloads_total": self.unloads_total,
            "evictions_total": self.evictions_total,
            "eviction_refusals": self.eviction_refusals,
        }

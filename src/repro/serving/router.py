"""Replica routing with health tracking.

The upstream worker of a replicated stage (paper Fig. 2: P1 feeding P2/P3)
routes each payload to one healthy replica world. When a world breaks the
router drops it from rotation (fault tolerance); OnlineInstantiator can
register replacement worlds at any time (online scaling); the elastic
controller can *gracefully* retire a world with :meth:`remove` (scale-down
drain) — unlike ``mark_broken``, removal forgets the world entirely so a
later replica reusing the name starts clean.

Two pick disciplines:

* :meth:`pick` — round robin over the healthy set (the paper's default).
* :meth:`pick_least_loaded` — joins the shortest downstream queue, via a
  load probe installed with :meth:`set_load_probe` (the elastic control
  plane wires this to per-replica inbox depth); falls back to
  fewest-routed-so-far when no probe is installed.

Role-specialized pools (disaggregated serving): each world is tagged with
the receiving replica's role (``prefill`` / ``decode`` / ``both``) at
:meth:`add` time. A pick with ``role=`` restricts the rotation to worlds
whose replica can serve that role — PREFILLs land in the prefill pool,
while ``both`` worlds (the colocated default) serve everything, so a
pipeline with no split pools routes exactly as before.

Model-tagged routing (multi-model pools): a world may additionally carry
the set of models resident on the replica behind it (``add(models=...)``,
updated live by :meth:`set_models` as the residency protocol loads/unloads
weights). A pick with ``model=`` restricts the rotation to worlds whose
replica hosts that model. Untagged worlds (``models=None``) serve any
model — the single-model pipeline never tags, so it routes exactly as
before — and ``model=None`` picks ignore tags entirely.

Probe hygiene: ``remove``/``mark_broken`` prune the world's routed history,
and ``remove`` additionally fires the drop listener
(:meth:`set_drop_listener`) so the owner can forget its side of the load
probe in the same tick — ``pick_least_loaded`` must never score a dead or
retired world, not even through a stale probe target left behind by the
callback. (Fenced worlds keep their owner-side mapping until teardown needs
it; the owner's probe guards them by health instead.)

Empty-rotation safety: ``pick`` raises (legacy behavior, callers that can't
wait), while ``try_pick``/``wait_healthy`` let a sender park a payload until
a world is added instead of dying — a replica must survive the window where
every downstream replica is gone and the controller is still healing.

Sticky session affinity (the generative data plane): a decode step must
return to the replica holding its KV cache, so the sender pins (:meth:`pin`)
the world chosen at prefill time and later routes the session's steps through
:meth:`pinned`. Pins are health-aware: a world leaving rotation — fenced by
the watchdog (``mark_broken``) or gracefully retired (``remove``, the drain
path) — drops every session pinned to it, and ``pinned`` returns ``None``,
which is the sender's signal that the session state is gone and the client
must re-prefill on a survivor.
"""
from __future__ import annotations

import asyncio
import itertools
from typing import Callable, Hashable, Optional

from .envelope import ROLE_BOTH, ROLE_CAPABLE


class ReplicaRouter:
    def __init__(self, worlds: Optional[list[str]] = None) -> None:
        self._worlds: list[str] = list(worlds or [])
        self._dead: set[str] = set()
        self._rr = itertools.count()
        self.routed: dict[str, int] = {}
        #: world -> role of the replica behind it (both = serves everything)
        self._roles: dict[str, str] = {}
        #: world -> models resident on the replica behind it; None (or
        #: absent) = untagged, serves any model
        self._models: dict[str, Optional[frozenset]] = {}
        #: session id -> world holding that session's downstream state
        self._pins: dict[Hashable, str] = {}
        #: optional world -> load metric (lower is better); see set_load_probe
        self._load_probe: Optional[Callable[[str], float]] = None
        #: fired when a world leaves rotation (remove/mark_broken) so the
        #: owner can prune its side of the load probe in the same tick
        self._drop_listener: Optional[Callable[[str], None]] = None
        self._nonempty = asyncio.Event()
        if self._worlds:
            self._nonempty.set()

    # -- membership ----------------------------------------------------------
    def add(self, world: str, role: str = ROLE_BOTH,
            models=None) -> None:
        if world not in self._worlds:
            self._worlds.append(world)
        self._roles[world] = role
        if models is not None:
            self._models[world] = frozenset(models)
        self._dead.discard(world)
        self._nonempty.set()

    def role_of(self, world: str) -> str:
        return self._roles.get(world, ROLE_BOTH)

    def set_models(self, world: str, models) -> None:
        """Live residency update: the replica behind ``world`` now hosts
        exactly ``models`` (None clears the tag — serves any model). The
        LOAD/UNLOAD/SWAP protocol calls this on every upstream edge the
        moment residency changes, so in-rotation swaps retarget routing
        without the world ever leaving the healthy set."""
        if models is None:
            self._models.pop(world, None)
        else:
            self._models[world] = frozenset(models)

    def models_of(self, world: str) -> Optional[frozenset]:
        return self._models.get(world)

    def mark_broken(self, world: str) -> None:
        # routed history pruned too: the no-probe fallback of
        # pick_least_loaded must not keep weighing a fenced world's past
        self._dead.add(world)
        self.routed.pop(world, None)
        self._drop_pins(world)
        if not self.healthy():
            self._nonempty.clear()

    def remove(self, world: str) -> None:
        """Graceful retirement: forget the world entirely (scale-down path)."""
        if world in self._worlds:
            self._worlds.remove(world)
        self._dead.discard(world)
        self.routed.pop(world, None)
        self._roles.pop(world, None)
        self._models.pop(world, None)
        self._drop_pins(world)
        self._notify_drop(world)
        if not self.healthy():
            self._nonempty.clear()

    def _notify_drop(self, world: str) -> None:
        if self._drop_listener is not None:
            self._drop_listener(world)

    # -- session affinity -----------------------------------------------------
    def pin(self, session_id: Hashable, world: str) -> None:
        """Stick a session to the world that holds its decode state."""
        self._pins[session_id] = world

    def pinned(self, session_id: Hashable) -> Optional[str]:
        """The session's world while it is still healthy, else None (state
        lost — caller must trigger re-prefill)."""
        world = self._pins.get(session_id)
        if world is None:
            return None
        if world not in self._worlds or world in self._dead:
            del self._pins[session_id]
            return None
        return world

    def unpin(self, session_id: Hashable) -> None:
        self._pins.pop(session_id, None)

    @property
    def pinned_sessions(self) -> int:
        return len(self._pins)

    def _drop_pins(self, world: str) -> None:
        for sid in [s for s, w in self._pins.items() if w == world]:
            del self._pins[sid]

    def healthy(self, role: Optional[str] = None,
                model: Optional[str] = None) -> list[str]:
        live = [w for w in self._worlds if w not in self._dead]
        if role is not None:
            capable = ROLE_CAPABLE.get(role, (role, ROLE_BOTH))
            live = [w for w in live
                    if self._roles.get(w, ROLE_BOTH) in capable]
        if model is not None:
            live = [w for w in live
                    if (tags := self._models.get(w)) is None or model in tags]
        return live

    @property
    def worlds(self) -> list[str]:
        """All worlds in rotation, healthy or broken (teardown iterates this)."""
        return list(self._worlds)

    # -- routing --------------------------------------------------------------
    def set_load_probe(self, probe: Optional[Callable[[str], float]]) -> None:
        """Install a world -> current-load function used by pick_least_loaded."""
        self._load_probe = probe

    def set_drop_listener(self, cb: Optional[Callable[[str], None]]) -> None:
        """Install a callback fired whenever a world leaves rotation, so the
        load-probe owner can forget the world's probe target immediately —
        without it, ``pick_least_loaded``'s probe could keep consulting a
        retired replica's counters through a stale mapping."""
        self._drop_listener = cb

    def pick(self, role: Optional[str] = None,
             model: Optional[str] = None) -> str:
        live = self.healthy(role, model)
        if not live:
            raise RuntimeError("no healthy replica worlds"
                               + (f" for role {role!r}" if role else "")
                               + (f" for model {model!r}" if model else ""))
        world = live[next(self._rr) % len(live)]
        self.routed[world] = self.routed.get(world, 0) + 1
        return world

    def pick_least_loaded(self, role: Optional[str] = None,
                          model: Optional[str] = None) -> str:
        live = self.healthy(role, model)
        if not live:
            raise RuntimeError("no healthy replica worlds"
                               + (f" for role {role!r}" if role else "")
                               + (f" for model {model!r}" if model else ""))
        if self._load_probe is not None:
            world = min(live, key=self._load_probe)
        else:
            world = min(live, key=lambda w: self.routed.get(w, 0))
        self.routed[world] = self.routed.get(world, 0) + 1
        return world

    def try_pick(self, least_loaded: bool = False,
                 role: Optional[str] = None,
                 model: Optional[str] = None) -> Optional[str]:
        """Like pick()/pick_least_loaded() but returns None when rotation is
        empty, so callers can park instead of crash."""
        if not self.healthy(role, model):
            return None
        return (self.pick_least_loaded(role, model) if least_loaded
                else self.pick(role, model))

    async def wait_healthy(self) -> None:
        """Park until at least one healthy world is in rotation."""
        while not self.healthy():
            self._nonempty.clear()
            await self._nonempty.wait()

"""Replica routing with health tracking.

The upstream worker of a replicated stage (paper Fig. 2: P1 feeding P2/P3)
routes each payload to one healthy replica world. When a world breaks the
router drops it from rotation (fault tolerance); OnlineInstantiator can
register replacement worlds at any time (online scaling).
"""
from __future__ import annotations

import itertools
from typing import Optional


class ReplicaRouter:
    def __init__(self, worlds: Optional[list[str]] = None) -> None:
        self._worlds: list[str] = list(worlds or [])
        self._dead: set[str] = set()
        self._rr = itertools.count()
        self.routed: dict[str, int] = {}

    # -- membership ----------------------------------------------------------
    def add(self, world: str) -> None:
        if world not in self._worlds:
            self._worlds.append(world)
        self._dead.discard(world)

    def mark_broken(self, world: str) -> None:
        self._dead.add(world)

    def healthy(self) -> list[str]:
        return [w for w in self._worlds if w not in self._dead]

    # -- routing --------------------------------------------------------------
    def pick(self) -> str:
        live = self.healthy()
        if not live:
            raise RuntimeError("no healthy replica worlds")
        world = live[next(self._rr) % len(live)]
        self.routed[world] = self.routed.get(world, 0) + 1
        return world

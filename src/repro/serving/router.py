"""Replica routing with health tracking.

The upstream worker of a replicated stage (paper Fig. 2: P1 feeding P2/P3)
routes each payload to one healthy replica world. When a world breaks the
router drops it from rotation (fault tolerance); OnlineInstantiator can
register replacement worlds at any time (online scaling); the elastic
controller can *gracefully* retire a world with :meth:`remove` (scale-down
drain) — unlike ``mark_broken``, removal forgets the world entirely so a
later replica reusing the name starts clean.

Two pick disciplines:

* :meth:`pick` — round robin over the healthy set (the paper's default).
* :meth:`pick_least_loaded` — joins the shortest downstream queue, via a
  load probe installed with :meth:`set_load_probe` (the elastic control
  plane wires this to per-replica inbox depth); falls back to
  fewest-routed-so-far when no probe is installed.

Empty-rotation safety: ``pick`` raises (legacy behavior, callers that can't
wait), while ``try_pick``/``wait_healthy`` let a sender park a payload until
a world is added instead of dying — a replica must survive the window where
every downstream replica is gone and the controller is still healing.

Sticky session affinity (the generative data plane): a decode step must
return to the replica holding its KV cache, so the sender pins (:meth:`pin`)
the world chosen at prefill time and later routes the session's steps through
:meth:`pinned`. Pins are health-aware: a world leaving rotation — fenced by
the watchdog (``mark_broken``) or gracefully retired (``remove``, the drain
path) — drops every session pinned to it, and ``pinned`` returns ``None``,
which is the sender's signal that the session state is gone and the client
must re-prefill on a survivor.
"""
from __future__ import annotations

import asyncio
import itertools
from typing import Callable, Hashable, Optional


class ReplicaRouter:
    def __init__(self, worlds: Optional[list[str]] = None) -> None:
        self._worlds: list[str] = list(worlds or [])
        self._dead: set[str] = set()
        self._rr = itertools.count()
        self.routed: dict[str, int] = {}
        #: session id -> world holding that session's downstream state
        self._pins: dict[Hashable, str] = {}
        #: optional world -> load metric (lower is better); see set_load_probe
        self._load_probe: Optional[Callable[[str], float]] = None
        self._nonempty = asyncio.Event()
        if self._worlds:
            self._nonempty.set()

    # -- membership ----------------------------------------------------------
    def add(self, world: str) -> None:
        if world not in self._worlds:
            self._worlds.append(world)
        self._dead.discard(world)
        self._nonempty.set()

    def mark_broken(self, world: str) -> None:
        self._dead.add(world)
        self._drop_pins(world)
        if not self.healthy():
            self._nonempty.clear()

    def remove(self, world: str) -> None:
        """Graceful retirement: forget the world entirely (scale-down path)."""
        if world in self._worlds:
            self._worlds.remove(world)
        self._dead.discard(world)
        self.routed.pop(world, None)
        self._drop_pins(world)
        if not self.healthy():
            self._nonempty.clear()

    # -- session affinity -----------------------------------------------------
    def pin(self, session_id: Hashable, world: str) -> None:
        """Stick a session to the world that holds its decode state."""
        self._pins[session_id] = world

    def pinned(self, session_id: Hashable) -> Optional[str]:
        """The session's world while it is still healthy, else None (state
        lost — caller must trigger re-prefill)."""
        world = self._pins.get(session_id)
        if world is None:
            return None
        if world not in self._worlds or world in self._dead:
            del self._pins[session_id]
            return None
        return world

    def unpin(self, session_id: Hashable) -> None:
        self._pins.pop(session_id, None)

    @property
    def pinned_sessions(self) -> int:
        return len(self._pins)

    def _drop_pins(self, world: str) -> None:
        for sid in [s for s, w in self._pins.items() if w == world]:
            del self._pins[sid]

    def healthy(self) -> list[str]:
        return [w for w in self._worlds if w not in self._dead]

    @property
    def worlds(self) -> list[str]:
        """All worlds in rotation, healthy or broken (teardown iterates this)."""
        return list(self._worlds)

    # -- routing --------------------------------------------------------------
    def set_load_probe(self, probe: Optional[Callable[[str], float]]) -> None:
        """Install a world -> current-load function used by pick_least_loaded."""
        self._load_probe = probe

    def pick(self) -> str:
        live = self.healthy()
        if not live:
            raise RuntimeError("no healthy replica worlds")
        world = live[next(self._rr) % len(live)]
        self.routed[world] = self.routed.get(world, 0) + 1
        return world

    def pick_least_loaded(self) -> str:
        live = self.healthy()
        if not live:
            raise RuntimeError("no healthy replica worlds")
        if self._load_probe is not None:
            world = min(live, key=self._load_probe)
        else:
            world = min(live, key=lambda w: self.routed.get(w, 0))
        self.routed[world] = self.routed.get(world, 0) + 1
        return world

    def try_pick(self, least_loaded: bool = False) -> Optional[str]:
        """Like pick()/pick_least_loaded() but returns None when rotation is
        empty, so callers can park instead of crash."""
        if not self.healthy():
            return None
        return self.pick_least_loaded() if least_loaded else self.pick()

    async def wait_healthy(self) -> None:
        """Park until at least one healthy world is in rotation."""
        while not self.healthy():
            self._nonempty.clear()
            await self._nonempty.wait()

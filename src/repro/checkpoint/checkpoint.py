"""Pytree checkpointing with mesh-aware restore.

Layout: ``<dir>/step_<N>/manifest.json`` + one ``.npy`` per leaf, keyed by
its pytree path. Restore can re-place leaves under any sharding tree
(``shardings=``) — the path MultiWorld online instantiation uses to bring a
replacement stage up on a *different* device slice than the one that failed.

bfloat16 has no numpy dtype; those leaves are stored as uint16 raw bits with
the true dtype recorded in the manifest.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    out = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(out, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    manifest = {"step": step, "leaves": {}}
    for i, (path, leaf) in enumerate(flat):
        key = _path_str(path)
        fname = f"leaf_{i:05d}.npy"
        arr = np.asarray(leaf)
        stored_dtype = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            stored_dtype = "bfloat16"
        np.save(os.path.join(out, fname), arr)
        manifest["leaves"][key] = {"file": fname, "dtype": stored_dtype,
                                   "shape": list(np.shape(arr))}
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return out


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for name in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", name))]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, like: Any,
                    shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional congruent tree of Shardings
    for device placement (mesh-aware reshard on restore)."""
    src = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
                    if shardings is not None else [None] * len(flat))
    assert len(shard_leaves) == len(flat)

    leaves = []
    for (path, leaf), sh in zip(flat, shard_leaves):
        key = _path_str(path)
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(src, meta["file"]))
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        want = jax.ShapeDtypeStruct(leaf.shape, leaf.dtype)
        assert tuple(arr.shape) == want.shape, (key, arr.shape, want.shape)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jnp.asarray(arr, want.dtype))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])

"""End-to-end driver: train a reduced llama on synthetic Markov data.

Runs a few hundred AdamW steps on CPU; loss drops from ~uniform (ln 64 ≈
4.16 over the effective successor set) toward the bigram entropy floor.
Checkpoints at the end and verifies a reload reproduces the logits.

  PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_smoke
from repro.models import build_model
from repro.training import (
    AdamWConfig,
    init_opt_state,
    make_stream,
    make_train_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--ckpt", default="/tmp/repro_train_small")
    args = ap.parse_args()

    cfg = get_smoke("llama3.2-1b").with_(vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.arch_id} (reduced) {n/1e6:.2f}M params")

    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    opt = init_opt_state(params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    stream = make_stream(cfg, args.batch, args.seq, seed=0)

    t0 = time.monotonic()
    first = None
    for step in range(1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        first = first or loss
        if step % 20 == 0 or step == 1:
            tok_s = step * args.batch * args.seq / (time.monotonic() - t0)
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  {tok_s:,.0f} tok/s")
    print(f"loss: {first:.3f} -> {loss:.3f}")
    assert loss < first, "training must reduce loss"

    out = save_checkpoint(args.ckpt, args.steps, params)
    print("checkpoint:", out)
    restored = load_checkpoint(args.ckpt, latest_step(args.ckpt),
                               model.abstract_params())
    toks = jnp.zeros((1, 8), jnp.int32)
    np.testing.assert_allclose(
        np.asarray(model.forward(params, toks)[0]),
        np.asarray(model.forward(restored, toks)[0]), rtol=1e-6)
    print("checkpoint round-trip verified")


if __name__ == "__main__":
    main()

"""End-to-end driver: elastic model serving (the paper's Fig. 2, live).

A llama-family model is split into 3 pipeline stages with the middle stage
replicated (the rhombus). The script serves real requests, kills a replica
mid-traffic (serving continues through the survivor), then performs online
instantiation of a replacement (serving capacity is restored) — all without
restarting any worker.

  PYTHONPATH=src python examples/serve_pipeline.py
"""
import asyncio
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core import Cluster, FailureKind
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import PipelineServer


async def main() -> None:
    cfg = get_smoke("llama3.2-1b").with_(num_layers=4,
                                         groups=(BlockGroup(DENSE, 4),))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    cluster = Cluster(heartbeat_interval=0.02, heartbeat_timeout=0.2)
    server = PipelineServer(cluster, model, params, replicas=[1, 2, 1])
    await server.start()
    print("pipeline: stage0 x1 -> stage1 x2 (replicated) -> stage2 x1")

    rng = np.random.default_rng(0)

    async def serve(n, tag):
        lat = []
        for _ in range(n):
            toks = rng.integers(0, cfg.vocab_size, (1, 16))
            t0 = time.monotonic()
            logits = await server.submit(toks, timeout=30.0)
            lat.append((time.monotonic() - t0) * 1e3)
            assert logits.shape == (1, 16, cfg.vocab_size)
        print(f"  [{tag}] {n} requests ok, mean latency "
              f"{sum(lat)/len(lat):.1f} ms")

    await serve(5, "healthy")
    loads = {r.worker_id: r.processed for r in server.replicas[1]}
    print("  stage-1 load:", loads)

    victim = server.replicas[1][0].worker_id
    print(f"\n-- killing {victim} (silent hang; watchdog must catch it) --")
    cluster.kill(victim, FailureKind.SILENT_HANG)
    await asyncio.sleep(0.5)
    await serve(5, "degraded: one replica down")

    print("\n-- online instantiation of a replacement replica --")
    new_id = await server.add_replica(1)
    print(f"  {new_id} joined stage 1 (fresh worlds, no restarts)")
    await serve(6, "healed")
    loads = {r.worker_id: r.processed for r in server.replicas[1]
             if r.worker.alive}
    print("  stage-1 load:", loads)

    cluster.shutdown()


if __name__ == "__main__":
    asyncio.run(main())

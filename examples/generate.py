"""Batched generation with the serving engine across architecture families.

Prefill + KV-cache decode (ring buffers for sliding-window layers, SSM
states for mamba/zamba) on reduced configs — every family's serve path in
one script.

  PYTHONPATH=src python examples/generate.py [--arch mamba2-2.7b ...]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke
from repro.models import build_model
from repro.serving import ServeEngine

DEFAULT = ["llama3.2-1b", "gemma2-2b", "mamba2-2.7b", "zamba2-2.7b",
           "mixtral-8x7b"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="+", default=DEFAULT,
                    choices=list(ARCH_IDS))
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    for arch in args.arch:
        cfg = get_smoke(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, max_len=64, temperature=0.0)
        prompts = rng.integers(0, cfg.vocab_size, (2, 8))
        t0 = time.monotonic()
        out = engine.generate(prompts, args.new_tokens)
        dt = time.monotonic() - t0
        print(f"{arch:22s} [{cfg.family:6s}] generated {out.shape} in "
              f"{dt:5.1f}s  sample: {out[0][:8].tolist()}")


if __name__ == "__main__":
    main()

"""End-to-end driver: elastic *generative* serving (the paper's Fig. 2
topology carrying real autoregressive decode traffic).

A llama-family model is split into 2 pipeline stages, the decode stage
replicated. Eight concurrent sessions stream tokens through the pipeline —
each stage holds a per-session KV cache over its own layer slice, decode
steps follow the session's pinned route, and the per-replica micro-scheduler
fuses compatible steps into batched dispatches. Mid-generation one replica
is killed: the watchdog fences its worlds, every affected session re-prefills
its full history (prompt + tokens generated so far) on a survivor, and all
outputs stay token-identical to a single-engine greedy decode.

  PYTHONPATH=src python examples/serve_generate.py
"""
import asyncio
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core import Cluster, FailureKind
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import PipelineServer, ServeEngine


async def main() -> None:
    cfg = get_smoke("llama3.2-1b").with_(num_layers=4,
                                         groups=(BlockGroup(DENSE, 4),))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    cluster = Cluster(heartbeat_interval=0.02, heartbeat_timeout=0.2)
    server = PipelineServer(cluster, model, params, replicas=[1, 2],
                            max_len=64, least_loaded=True)
    await server.start()
    print("pipeline: stage0 x1 -> stage1 x2 (replicated decode stage)")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (1, 8)) for _ in range(8)]
    engine = ServeEngine(model, params, max_len=64)
    wants = [engine.generate(p, 8) for p in prompts]
    print("reference single-engine greedy decodes computed")

    async def one(p):
        return await server.generate(p, 8, step_timeout=10.0)

    t0 = time.monotonic()
    tasks = [asyncio.ensure_future(one(p)) for p in prompts]
    await asyncio.sleep(0.1)
    victim = server.replicas[1][0].worker_id
    print(f"-- killing {victim} mid-generation (silent hang) --")
    cluster.kill(victim, FailureKind.SILENT_HANG)
    outs = await asyncio.gather(*tasks)
    dt = time.monotonic() - t0

    exact = sum(bool(np.array_equal(o, w)) for o, w in zip(outs, wants))
    print(f"  8 sessions x 8 tokens in {dt:.2f}s "
          f"({8 * 8 / dt:.1f} tok/s), {exact}/8 token-identical to the "
          f"single engine")
    assert exact == 8

    stats = server.replica_stats()
    for wid, s in stats.items():
        if s["decode_steps"]:
            print(f"  {wid}: {s['decode_steps']} decode steps in "
                  f"{s['decode_batches']} fused dispatches, "
                  f"{s['retries_sent']} sessions bounced for re-prefill")
    cluster.shutdown()


if __name__ == "__main__":
    asyncio.run(main())

"""End-to-end driver: the elastic control plane, live.

Where examples/serve_pipeline.py performs the paper's Fig. 2 scenario *by
hand* (you kill, you add), this script hands the pipeline to the
ElasticController and only injects traffic and one failure:

  1. a 2-stage pipeline starts at [1, 1] replicas under calm Poisson traffic
  2. a flash crowd arrives -> per-replica backlog crosses the policy target
     -> the controller scales stages out via online instantiation
  3. one scaled replica is killed (silent hang) -> watchdogs fence its
     worlds -> the controller replaces it, no operator involved
  4. the crowd leaves -> the controller drains-and-removes surplus replicas
     back to the floor, with zero in-flight request loss

Generative sessions run throughout (with background snapshots on), so the
scale-down drains are *live handoffs*: open KV sessions migrate to
survivors instead of re-prefilling — the state-transfer metrics printed at
the end show moved-vs-recomputed work.

  PYTHONPATH=src python examples/serve_elastic.py
"""
import asyncio
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.control import (
    BurstProfile,
    ElasticController,
    HysteresisPolicy,
    MetricsHub,
    OpenLoopGenerator,
    TargetQueueDepthPolicy,
)
from repro.core import Cluster, FailureKind
from repro.models import DENSE, BlockGroup, build_model
from repro.obs import SLOMonitor, SLOSpec
from repro.obs.export import write_trace_artifact
from repro.serving import PipelineServer


async def main() -> None:
    cfg = get_smoke("llama3.2-1b").with_(num_layers=2,
                                         groups=(BlockGroup(DENSE, 2),))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    cluster = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.1)
    # fleet-scale telemetry knobs: head-sample half the session trees (tail
    # keep rules still promote every heal/migrate/slow-outlier trace), and
    # keep anything slower than 2 s regardless of the sampling verdict
    server = PipelineServer(cluster, model, params, replicas=[1, 1],
                            least_loaded=True, snapshot_interval_s=0.1,
                            trace_sample_rate=0.5, trace_slow_keep_s=2.0)
    await server.start()
    print("pipeline up: stage0 x1 -> stage1 x1 (floor), snapshots on, "
          "tracing head-sampled at 50%")

    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (8, 64))
    await server.submit(toks)                       # warm compiles
    t0 = time.monotonic()
    for _ in range(10):
        await server.submit(toks)
    capacity = 10 / (time.monotonic() - t0)
    print(f"single-replica capacity ~{capacity:.0f} req/s")

    # SLO burn-rate alerting rides on the hub: a steady run stays quiet,
    # a real regression lands slo_alert events in the flight recorder and
    # the control timeline next to the scale decisions they explain
    slo = SLOMonitor((SLOSpec("ttft_p99", "ttft", threshold_s=2.0),
                      SLOSpec("decode_p99", "decode", threshold_s=2.0)),
                     pipeline="serve_elastic", bucket_s=0.25)
    ctrl = ElasticController(
        server,
        HysteresisPolicy(
            TargetQueueDepthPolicy(target=3.0, scale_down_at=0.3,
                                   min_replicas=1, max_replicas=4),
            confirm=2, cooldown_s=0.8),
        hub=MetricsHub(server, slo=slo),
        interval=0.05)
    ctrl.start()
    print("controller on: observe -> decide -> act every 50 ms\n")

    gen = OpenLoopGenerator(
        lambda: server.submit(toks, timeout=4.0, retries=3),
        BurstProfile(base=max(1.0, 0.15 * capacity),
                     burst=min(100.0, 1.35 * capacity), t0=1.0, t1=3.0),
        seed=1)

    async def chaos():
        # wait for the controller to scale out, then kill a scaled replica
        while True:
            await asyncio.sleep(0.05)
            scaled = [s for s in range(server.n_stages)
                      if len(server.healthy_replicas(s)) > 1]
            if scaled:
                victim = server.healthy_replicas(scaled[0])[0]
                print(f"-- killing {victim} (silent hang) --")
                cluster.kill(victim, FailureKind.SILENT_HANG)
                return

    async def generate_sessions():
        # a trickle of open generative sessions rides through every scale
        # event; drains hand their KV state off live instead of re-prefilling
        # (short step timeout: a session wedged by the kill recovers via
        # snapshot restore instead of stalling out the trickle)
        while True:
            p = rng.integers(0, cfg.vocab_size, (1, 12))
            await server.generate(p, 8, step_timeout=5.0)
            await asyncio.sleep(0.05)

    chaos_task = asyncio.ensure_future(chaos())
    sessions_task = asyncio.ensure_future(generate_sessions())
    summary = await gen.run(8.0)
    await asyncio.sleep(1.5)                        # let scale-down finish
    await ctrl.step()
    await ctrl.stop()
    chaos_task.cancel()
    sessions_task.cancel()

    # explicit live-handoff beat: scale the decode stage out, open sessions
    # across both replicas, then drain one *while they are mid-decode* — the
    # sessions move, they do not re-prefill
    await server.add_replica(1)
    open_tasks = [
        asyncio.ensure_future(server.generate(
            rng.integers(0, cfg.vocab_size, (1, 12)), 16, step_timeout=10.0))
        for _ in range(4)]
    while sum(r.open_sessions() for r in server.replicas[1]) < 4:
        await asyncio.sleep(0.005)
    victim = max((r for r in server.replicas[1]
                  if r.worker.alive and not r.draining),
                 key=lambda r: r.open_sessions())
    print(f"\n-- draining {victim.worker_id} with "
          f"{victim.open_sessions()} open sessions (live handoff) --")
    await server.remove_replica(1, victim.worker_id, drain=True)
    await asyncio.gather(*open_tasks)

    start = min(e.t for e in ctrl.timeline) if ctrl.timeline else 0.0
    print("\ncontrol timeline:")
    for e in ctrl.timeline:
        print(f"  {e.t - start:6.2f}s  {e.kind:<11} stage{e.stage}  {e.detail}")
    print(f"\ntraffic: {summary['ok']} ok / {summary['failed']} failed "
          f"(p50 {summary['p50_s'] * 1e3:.0f} ms, "
          f"p95 {summary['p95_s'] * 1e3:.0f} ms)")
    print(f"controller: {ctrl.scale_ups} scale-ups, {ctrl.heals} heals, "
          f"{ctrl.scale_downs} drain-and-removes; "
          f"final replicas {ctrl.replica_counts()}")
    mm = ctrl.hub.migration_metrics()
    print(f"state transfer: {mm['migrations_total']} live handoffs "
          f"(p50 {mm['migration_p50_s'] * 1e3:.1f} ms), "
          f"{mm['restores_total']} snapshot restores, "
          f"{mm['reprefills_total']} re-prefill fallbacks; "
          f"snapshot ~{mm['snapshot_bytes_ewma'] / 1e3:.0f} KB "
          f"({mm.get('delta_snapshots_total', 0)} delta snapshots, "
          f"{mm.get('snapshot_delta_bytes_total', 0) / 1e3:.0f} KB of "
          f"{mm.get('snapshot_bytes_total', 0) / 1e3:.0f} KB); "
          f"tokens recovered/recomputed "
          f"{mm['recovered_tokens']}/{mm['recomputed_tokens']}; "
          f"deadline drops {mm['deadline_expired_total']}")
    # latency split via the supported obs surface: the tracer's per-kind
    # span digests (the hub drains its raw latency logs on every poll, so
    # reaching into those would race the controller)
    ts = ctrl.hub.trace_summary()
    ttft = ts.get("ttft", {})
    dstep = ts.get("decode_step", {})
    print(f"latency split: TTFT p50 {ttft.get('p50_s', 0.0) * 1e3:.1f} ms "
          f"/ p95 {ttft.get('p95_s', 0.0) * 1e3:.1f} ms (prefill "
          f"round-trip, n={ttft.get('count', 0)}), decode p50 "
          f"{dstep.get('p50_s', 0.0) * 1e3:.1f} ms/token "
          f"(n={dstep.get('count', 0)}) — the per-role scaling signals")
    recov = {k: v for k, v in ts.items()
             if k in ("handoff", "migrate", "restore", "restore_replay",
                      "heal", "reprefill") and v.get("count")}
    if recov:
        print("recovery spans: " + "; ".join(
            f"{k} n={v['count']} p50 {v['p50_s'] * 1e3:.1f} ms"
            for k, v in sorted(recov.items())))
    # the fleet digest: the bounded mergeable rollup the policies read —
    # tail percentiles come from merged sketches, not averaged averages
    fd = ctrl.hub.fleet_digest()
    print(f"fleet digest: {fd.n_replicas} healthy replicas, queue "
          f"{fd.queue_total}, p95 TTFT {fd.p95_ttft_s * 1e3:.1f} ms, "
          f"p99 decode {fd.p99_decode_s * 1e3:.1f} ms (merged sketches, "
          f"{fd.ttft_sketch.count + fd.decode_sketch.count} samples)")
    sm = slo.metrics(time.monotonic())
    print(f"slo: ttft_p99 burn long/short "
          f"{sm['ttft_p99_burn_long']:.2f}/{sm['ttft_p99_burn_short']:.2f}, "
          f"{ctrl.slo_alerts} alerts fired (steady run should stay quiet)")
    tr = server.tracer
    print(f"sampling: {tr.recorded} spans in ring, "
          f"{tr.sampled_out} boring traces dropped, "
          f"{tr.tail_kept} promoted by tail-keep rules")
    pm = ctrl.hub.placement_metrics()
    print(f"placement: {mm['heal_migrations_total']} heal handoffs; "
          f"{pm['cross_host_bytes'] / 1e3:.0f} KB of "
          f"{pm['bytes_sent'] / 1e3:.0f} KB crossed hosts "
          f"(bulk {pm['bulk_cross_host_bytes'] / 1e3:.0f} KB of "
          f"{pm['bulk_bytes'] / 1e3:.0f} KB); "
          f"cost-weighted total {pm['cost_weighted_bytes'] / 1e3:.0f}")
    art = write_trace_artifact(
        "TRACE_serve_elastic.json", suite="serve_elastic",
        tracer=server.tracer, recorder=server.recorder,
        extra={"heals": ctrl.heals, "scale_ups": ctrl.scale_ups})
    print(f"\ntrace artifact: TRACE_serve_elastic.json "
          f"({len(art['span_summary'])} span kinds, "
          f"{art['flight_events']} flight events, "
          f"{art['flight_dumps']} dumps)")
    assert summary["failed"] == 0

    # speculative-decoding beat: a draft pool on the same cluster — the
    # 1-layer draft (the target's own first layer, shared embeddings)
    # proposes k tokens per round, the target verifies them in one batched
    # dispatch, and the spec counters surface through the hub
    draft_cfg = cfg.with_(num_layers=1, groups=(BlockGroup(DENSE, 1),))
    draft_params = {k: v for k, v in params.items() if k != "groups"}
    draft_params["groups"] = [jax.tree.map(lambda a: a[:1],
                                           params["groups"][0])]
    spec_server = PipelineServer(cluster, model, params,
                                 replicas=[{"both": 1, "draft": 1}],
                                 draft_model=build_model(draft_cfg),
                                 draft_params=draft_params, spec_k=3)
    await spec_server.start()
    print("\n-- speculative decoding: {both:1, draft:1}, k=3 --")
    for _ in range(3):
        await spec_server.generate(
            rng.integers(0, cfg.vocab_size, (1, 12)), 12, step_timeout=30.0)
    spec = MetricsHub(spec_server).spec_metrics()
    print(f"spec: {spec['spec_rounds_total']} rounds, "
          f"{spec['accepted_tokens_total']}/{spec['proposed_tokens_total']}"
          f" draft tokens accepted "
          f"(acceptance {spec['acceptance_rate']:.2f}), "
          f"{spec['spec_fallbacks_total']} plain-decode fallbacks — "
          f"exported as the repro_spec_* Prometheus group")
    cluster.shutdown()


if __name__ == "__main__":
    asyncio.run(main())

"""Quickstart: MultiWorld in 60 seconds.

Creates two workers, a world, moves tensors through the fault-tolerant
communicator, kills a worker, and shows the surviving side getting a clean
WorldBrokenError instead of a hang — the paper's core promise.

  PYTHONPATH=src python examples/quickstart.py
"""
import asyncio

import jax.numpy as jnp

from repro.core import Cluster, FailureKind, WorldBrokenError


async def main() -> None:
    cluster = Cluster(heartbeat_interval=0.02, heartbeat_timeout=0.2)
    alice = cluster.worker("alice")
    bob = cluster.worker("bob")

    # rendezvous: both sides initialize the world (paper: initialize_world)
    await asyncio.gather(
        alice.manager.initialize_world("w1", rank=0, size=2),
        bob.manager.initialize_world("w1", rank=1, size=2),
    )
    print("world 'w1' is up:", alice.manager.worlds["w1"].members)

    # the 8 collective ops take the world name as an argument
    await alice.comm.send(jnp.arange(4.0), dst=1, world_name="w1")
    print("bob received:", await bob.comm.recv(src=0, world_name="w1"))

    total = await asyncio.gather(
        alice.comm.all_reduce(jnp.asarray([1.0]), "w1"),
        bob.comm.all_reduce(jnp.asarray([2.0]), "w1"),
    )
    print("all_reduce on both ranks:", [float(t[0]) for t in total])

    # fault tolerance: bob dies silently (the NCCL shared-memory case);
    # alice's pending recv aborts with an exception instead of hanging
    pending = asyncio.ensure_future(alice.comm.recv(1, "w1"))
    cluster.kill("bob", FailureKind.SILENT_HANG)
    try:
        await pending
    except WorldBrokenError as e:
        print("alice's pending recv aborted cleanly:", e)

    print("alice's healthy worlds now:", alice.manager.healthy_worlds())
    cluster.shutdown()


if __name__ == "__main__":
    asyncio.run(main())
